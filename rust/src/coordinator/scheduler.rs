//! The serving engine: chunked-prefill admission + batched decode loop.
//!
//! Scheduling policy (prefill-priority, like vLLM's default):
//! 1. Admit pending requests while state slots remain: prefill the prompt
//!    in bucket-sized chunks (largest bucket first, exact state chaining);
//!    a sub-bucket remainder is absorbed through single-token decode steps.
//! 2. Run one batched decode step over all active sequences (packed by the
//!    [`DecodeBatcher`]), sample greedily, retire finished requests.
//!
//! The engine is synchronous and deterministic (drive it with [`Engine::run`]
//! or step it manually in tests); `serve_threaded` in [`super::router`]
//! wraps it in a worker thread with mpsc queues.

use std::collections::VecDeque;
use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::backend::InferenceBackend;
use crate::obs::trace::TraceCtx;
use crate::obs::{Counter, FlightCtx, FlightKind, Telemetry, TraceSink};
use crate::statecache::StateCache;
use crate::util::json::{num, s, Json};

use super::admission::{finish_unadmitted, seed_from_cache, AdmissionSeed};
use super::batcher::{full_bucket_plan, DecodeBatcher};
use super::metrics::Metrics;
use super::request::{
    age_queue, insert_by_priority, Event, FinishReason, FinishedRequest, InFlight,
    Request, ResumeState, SchedPolicy, SubmitHandle,
};
use super::sampler::{OutStream, Sampler};
use super::state::StatePool;

#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// maximum concurrent sequences (state slots)
    pub max_active: usize,
    /// prompt chunk remainder threshold: remainders below the smallest
    /// prefill bucket run as decode steps
    pub greedy_chunking: bool,
}

impl Default for EngineConfig {
    fn default() -> Self {
        Self { max_active: 64, greedy_chunking: true }
    }
}

/// High-bit tag for the internal session ids preemption snapshots are
/// filed under in the state cache, keeping them out of the user
/// session-id space (a colliding user id would only see its entry
/// replaced by a newer snapshot — never wrong tokens, since session
/// lookups verify the stored transcript is a prefix of the prompt).
const PREEMPT_SID_TAG: u64 = 1 << 63;

/// One `/statusz` request-table row: the fields the hub's table (and the
/// stall watchdog, which keys on `id`/`tokens`) reads per live request.
/// Shared with [`super::speculative::SpecEngine`] so both engines publish
/// identical schemas.
pub(crate) fn status_row(
    req: &Request,
    state: &str,
    eff_priority: i64,
    tokens: usize,
    now: Instant,
) -> Json {
    Json::Obj(vec![
        ("id".to_string(), num(req.id as f64)),
        ("state".to_string(), s(state)),
        ("priority".to_string(), num(req.priority as f64)),
        ("effective_priority".to_string(), num(eff_priority as f64)),
        (
            "age_s".to_string(),
            num(now.saturating_duration_since(req.submitted_at).as_secs_f64()),
        ),
        ("tokens".to_string(), num(tokens as f64)),
        (
            "session".to_string(),
            match req.session_id {
                Some(sid) => num(sid as f64),
                None => Json::Null,
            },
        ),
    ])
}

pub struct Engine<'be> {
    be: &'be dyn InferenceBackend,
    cfg: EngineConfig,
    pool: StatePool,
    batcher: DecodeBatcher,
    prefill_buckets: Vec<usize>, // ascending
    /// shared SSM state cache (prefix reuse + session resume); `None`
    /// runs every prompt through full prefill
    cache: Option<Arc<StateCache>>,
    /// span-trace attachment (sink + worker lane); `None` = zero overhead
    trace: Option<TraceCtx>,
    /// flight-recorder attachment (shared ring + worker lane); `None` =
    /// zero overhead
    flight: Option<FlightCtx>,
    /// overload scheduling: priority aging, preemption, bounded queue.
    /// The default disables all three (static-priority pre-policy behavior)
    policy: SchedPolicy,
    pending: VecDeque<Request>,
    active: Vec<InFlight>,
    pub finished: Vec<FinishedRequest>,
    pub metrics: Metrics,
}

impl<'be> Engine<'be> {
    pub fn new(be: &'be dyn InferenceBackend, cfg: EngineConfig) -> Self {
        let pool = StatePool::new(be.cfg(), cfg.max_active);
        let batcher = DecodeBatcher::new(be.decode_batches());
        let prefill_buckets = be.prefill_buckets();
        Self {
            be,
            cfg,
            pool,
            batcher,
            prefill_buckets,
            cache: None,
            trace: None,
            flight: None,
            policy: SchedPolicy::default(),
            pending: VecDeque::new(),
            active: Vec::new(),
            finished: Vec::new(),
            metrics: Metrics::default(),
        }
    }

    /// Attach a (shared) SSM state cache: admissions seed from the longest
    /// cached prefix of the prompt (or the session's end-of-turn state)
    /// and prefill only the suffix; completed prefill chunks and
    /// end-of-turn states are inserted back.  Prefix hits are bit-exact
    /// with the uncached path (see [`crate::statecache`]).
    pub fn with_cache(mut self, cache: Arc<StateCache>) -> Self {
        self.cache = Some(cache);
        self
    }

    /// Attach live telemetry cells: every metrics mutation from here on
    /// also lands in `tel`'s shared atomics (Prometheus scrape / live log).
    pub fn with_telemetry(mut self, tel: Arc<Telemetry>) -> Self {
        self.metrics.attach_telemetry(tel);
        self
    }

    /// Attach a span-trace sink; `lane` identifies this engine's process
    /// lane in the exported Chrome trace.
    pub fn with_trace(mut self, sink: Arc<TraceSink>, lane: u32) -> Self {
        self.trace = Some(TraceCtx::new(sink, lane));
        self
    }

    /// Pool-worker trace attachment (the dispatcher already opened the
    /// request envelopes, so `ctx.record_queued` is false there).
    pub(crate) fn set_trace(&mut self, ctx: TraceCtx) {
        self.trace = Some(ctx);
    }

    /// Attach the shared flight recorder; `worker` is this engine's lane
    /// in the recorded events.  Every lifecycle transition (enqueue,
    /// admit, cache probe, preempt/resume, shed, finish) lands in the
    /// bounded ring from here on.
    pub fn with_flight(mut self, rec: Arc<crate::obs::FlightRecorder>, worker: u32) -> Self {
        self.flight = Some(FlightCtx::new(rec, worker));
        self
    }

    /// Pool-worker flight attachment (same pattern as [`Engine::set_trace`]).
    pub(crate) fn set_flight(&mut self, ctx: FlightCtx) {
        self.flight = Some(ctx);
    }

    /// Attach an overload-scheduling policy: priority aging
    /// (`age_rate` levels/second of queue wait), preemption
    /// (`preempt_threshold`, requires an attached state cache for the
    /// snapshot — see [`Engine::try_preempt`]), and bounded-queue
    /// admission control (`max_queue` sheds with
    /// [`FinishReason::Overloaded`]).
    pub fn with_policy(mut self, policy: SchedPolicy) -> Self {
        self.policy = policy;
        self
    }

    /// Queue a request and return its streaming [`SubmitHandle`] (events
    /// buffer until `step()`/`run()` produces them; dropping the handle
    /// reverts to batch-style collection through [`Engine::finished`]).
    pub fn submit(&mut self, mut req: Request) -> SubmitHandle {
        let handle = req.attach_events();
        self.enqueue(req);
        handle
    }

    /// Queue a request whose event channel was attached by an external
    /// submit path — the pool worker ([`super::router::ServePool::submit`]
    /// created the handle before the request crossed into this worker) or
    /// an HTTP frontend feeding requests through a channel
    /// ([`crate::server::ChannelSubmitter`]).
    pub fn enqueue(&mut self, req: Request) {
        if let Some(t) = &self.trace {
            if t.record_queued && t.sink.sampled(req.id) {
                t.sink.begin_request(req.id, req.prompt.len(), req.priority);
            }
        }
        if let Some(f) = &self.flight {
            f.record(
                req.id,
                FlightKind::Enqueue,
                format!("prompt={} priority={}", req.prompt.len(), req.priority),
            );
        }
        // admission control: a full pending queue sheds the arrival
        // immediately with a retriable terminal event (preempted requests
        // re-enter through `preempt`, never through here — a victim is
        // never shed)
        if self.policy.queue_full(self.pending.len()) {
            finish_unadmitted(
                &mut self.metrics,
                self.trace.as_ref(),
                self.flight.as_ref(),
                &mut self.finished,
                req,
                FinishReason::Overloaded,
            );
            return;
        }
        // admission-aware eviction: pin the cache keys this request will
        // seed from at admission (shed above happens before the pin, so a
        // shed request holds none)
        self.pin_queued(&req);
        insert_by_priority(&mut self.pending, req);
        self.metrics
            .note_queue_depth(self.pending.len() + self.active.len());
    }

    /// Pin the cache keys a queued request will be admitted from — its
    /// session entry and every bucket-boundary prefix of its prompt (for a
    /// preempted request, the preemption snapshot instead) — so LRU
    /// pressure between enqueue and admission cannot evict a snapshot the
    /// scheduler is committed to resuming from.  Balanced by
    /// [`Engine::unpin_queued`] the moment the request leaves the pending
    /// queue: admission, or unadmitted termination.
    fn pin_queued(&self, req: &Request) {
        let Some(cache) = &self.cache else { return };
        if let Some(r) = &req.resume {
            cache.pin_session(r.snapshot_sid);
            return;
        }
        let (chunks, _) = self.chunk_plan(req.prompt.len());
        cache.pin_request(&req.variant, &req.prompt, &chunks, req.session_id);
    }

    /// Balance one [`Engine::pin_queued`] (the chunk plan is deterministic
    /// in the prompt length, so the recomputed keys match exactly).
    fn unpin_queued(&self, req: &Request) {
        let Some(cache) = &self.cache else { return };
        if let Some(r) = &req.resume {
            cache.unpin_session(r.snapshot_sid);
            return;
        }
        let (chunks, _) = self.chunk_plan(req.prompt.len());
        cache.unpin_request(&req.variant, &req.prompt, &chunks, req.session_id);
    }

    pub fn n_pending(&self) -> usize {
        self.pending.len()
    }

    pub fn n_active(&self) -> usize {
        self.active.len()
    }

    /// Split a prompt length into prefill chunks (largest-bucket-first) and
    /// a decode-step remainder.  The remainder is always ≥ 1 so the final
    /// prompt token runs through decode and yields the logits that sample
    /// the first generated token.
    pub fn chunk_plan(&self, prompt_len: usize) -> (Vec<usize>, usize) {
        assert!(prompt_len >= 1, "empty prompt");
        // reserve the last token for decode
        let (chunks, rest) = full_bucket_plan(&self.prefill_buckets, prompt_len - 1);
        (chunks, rest + 1)
    }

    /// Admit pending requests (prefill) while capacity lasts.  Priority
    /// aging re-sorts the queue first (stable, by effective priority), and
    /// when the engine is full a qualifying front request may evict the
    /// lowest-priority running one (see [`Engine::try_preempt`]).
    fn admit(&mut self) -> Result<()> {
        if age_queue(&mut self.pending, &self.policy) {
            self.metrics.count(Counter::AgingReorders, 1);
        }
        while self.pending.front().is_some() {
            if self.pool.in_use() >= self.cfg.max_active {
                if !self.try_preempt() {
                    break;
                }
                continue; // a slot was freed; the front is the preemptor
            }
            let Some(slot) = self.pool.alloc() else {
                if !self.try_preempt() {
                    break;
                }
                continue;
            };
            let req = self.pending.pop_front().unwrap();
            // the request is leaving the queue: its snapshots are read
            // (and the state seeded) within this admission, so the
            // admission pins come off now
            self.unpin_queued(&req);
            if req.resume.is_some() {
                // a preempted request continues where it stopped
                self.admit_resumed(req, slot)?;
                continue;
            }
            // latency anchors at request creation, not admission: queue
            // time (engine pending list, pool dispatcher backlog) is part
            // of the user-visible TTFT
            let submitted = req.submitted_at;

            let (chunks, _) = self.chunk_plan(req.prompt.len());
            // state-cache seeding (shared with SpecEngine::admit — the two
            // admission paths must stay in lock-step for entry interchange)
            let AdmissionSeed { mut offset, chunks, mut done_chunks, prefix_cacheable } =
                seed_from_cache(
                    self.cache.as_ref(),
                    &mut self.pool,
                    &mut self.metrics,
                    slot,
                    &req.variant,
                    &req.prompt,
                    req.session_id,
                    &self.prefill_buckets,
                    chunks,
                );
            if let Some(t) = &self.trace {
                if t.sink.sampled(req.id) {
                    t.sink.instant(req.id, "admitted", vec![("slot", num(slot as f64))]);
                    if self.cache.is_some() {
                        t.sink.instant(
                            req.id,
                            "cache_probe",
                            vec![
                                ("hit", Json::Bool(offset > 0)),
                                ("tokens_saved", num(offset as f64)),
                            ],
                        );
                    }
                }
            }
            if let Some(f) = &self.flight {
                f.record(req.id, FlightKind::Admit, format!("slot={slot}"));
                if self.cache.is_some() {
                    f.record(
                        req.id,
                        FlightKind::CacheProbe,
                        format!("hit={} tokens_saved={offset}", offset > 0),
                    );
                }
            }
            // whatever the seeded coverage and remaining chunks, the
            // decode-path remainder is the uncovered tail (always >= 1:
            // chunk plans reserve the final prompt token)
            let remainder = req.prompt.len() - offset - chunks.iter().sum::<usize>();
            for chunk_len in chunks {
                let toks: Vec<i32> = req.prompt[offset..offset + chunk_len]
                    .iter()
                    .map(|t| *t as i32)
                    .collect();
                let st = self.pool.get(slot);
                let call_t0 = Instant::now();
                let out = self.be.prefill(&req.variant, &toks, &st.conv, &st.ssm)?;
                let call_s = call_t0.elapsed().as_secs_f64();
                let stm = self.pool.get_mut(slot);
                stm.conv = out.conv_state;
                stm.ssm = out.ssm_state;
                offset += chunk_len;
                self.metrics.note_prefill_call(call_s);
                self.metrics.count(Counter::PrefillChunks, 1);
                if let Some(t) = &self.trace {
                    if t.sink.sampled(req.id) {
                        t.sink.span_request(
                            req.id,
                            "prefill_chunk",
                            call_s,
                            vec![("len", num(chunk_len as f64))],
                        );
                    }
                }
                if prefix_cacheable {
                    // publish the boundary snapshot: the next request that
                    // shares this (variant, chunk-plan prefix, token prefix)
                    // skips straight past it — on any worker sharing the Arc
                    done_chunks.push(chunk_len);
                    if let Some(cache) = &self.cache {
                        let st = self.pool.get(slot);
                        cache.insert_prefix(
                            &req.variant,
                            &req.prompt[..offset],
                            &done_chunks,
                            &st.conv,
                            &st.ssm,
                        );
                    }
                }
            }
            // remainder through single-token decode steps (exact)
            let mut last_logits: Option<Vec<f32>> = None;
            for i in 0..remainder {
                let tok = req.prompt[offset + i] as i32;
                let st = self.pool.get(slot);
                let call_t0 = Instant::now();
                let out = self.be.decode(&req.variant, 1, &st.conv, &st.ssm, &[tok])?;
                self.metrics.note_decode_call(call_t0.elapsed().as_secs_f64());
                let stm = self.pool.get_mut(slot);
                stm.conv = out.conv_state;
                stm.ssm = out.ssm_state;
                last_logits = Some(out.logits);
                self.metrics.count(Counter::DecodeSteps, 1);
                self.metrics.count(Counter::DecodeBatchSlots, 1);
            }
            self.metrics
                .count(Counter::PromptTokens, req.prompt.len() as u64);

            // first generated token comes from the last prompt position
            // (chunk_plan guarantees remainder >= 1, so last_logits is set).
            // Default (pure greedy) params route through raw argmax inside
            // the sampler — bit-exact with the pre-sampler engine.
            let vocab = self.be.cfg().vocab_size;
            let mut sampler = Sampler::new(req.sampling.clone());
            sampler.observe_context(&req.prompt);
            let first =
                sampler.sample(&last_logits.expect("remainder >= 1")[..vocab], 0);
            sampler.observe(first);
            let stream = OutStream::new(&req.sampling);
            let now = Instant::now();
            let mut infl = InFlight {
                next_token: 0,
                slot,
                generated: Vec::new(),
                submitted,
                first_token_at: None,
                last_token_at: None,
                sampler,
                stream,
                req,
            };
            infl.next_token = first;
            infl.first_token_at = Some(now);
            infl.last_token_at = Some(now);
            infl.generated.push(first);
            infl.req.emit(Event::FirstToken);
            let stopped_seq = infl.stream.push(&infl.req, first);
            self.metrics.note_ttft(submitted.elapsed().as_secs_f64());
            self.metrics.count(Counter::TokensGenerated, 1);
            if let Some(t) = &self.trace {
                if t.sink.sampled(infl.req.id) {
                    t.sink.instant(infl.req.id, "first_token", Vec::new());
                }
            }
            // finished immediately?
            if stopped_seq {
                self.retire(infl, FinishReason::StopSequence);
            } else if infl.req.stop_token == Some(first) {
                self.retire(infl, FinishReason::StopToken);
            } else if infl.generated.len() >= infl.req.max_new_tokens {
                self.retire(infl, FinishReason::Length);
            } else {
                self.active.push(infl);
            }
        }
        Ok(())
    }

    /// Preemption check at a full engine: when the queue front's effective
    /// priority clears `preempt_threshold` and a strictly lower-priority
    /// (static) request is running, snapshot that victim's state into the
    /// state cache, free its slot, and requeue it carrying a
    /// [`ResumeState`].  The strict static-priority requirement is the
    /// no-livelock invariant: the requeued victim always sorts behind the
    /// preemptor, so the freed slot goes to the preemptor, never back to
    /// the victim.  Requires an attached state cache — re-prefilling a
    /// quantized variant under a different chunk plan would not be
    /// bit-exact, so without a cache preemption stays off.
    fn try_preempt(&mut self) -> bool {
        let Some(threshold) = self.policy.preempt_threshold else {
            return false;
        };
        if self.cache.is_none() {
            return false;
        }
        let Some(front) = self.pending.front() else {
            return false;
        };
        // an already-preempted request never preempts in turn: one snapshot
        // per victim at a time keeps preemption from thrashing
        if front.resume.is_some()
            || self.policy.effective_priority(front, Instant::now()) < threshold as i64
        {
            return false;
        }
        let front_priority = front.priority;
        let victim = self
            .active
            .iter()
            .enumerate()
            .min_by_key(|(_, a)| (a.req.priority, a.generated.len(), a.req.id))
            .map(|(i, _)| i);
        let Some(vi) = victim else { return false };
        if self.active[vi].req.priority >= front_priority {
            return false;
        }
        let infl = self.active.swap_remove(vi);
        self.preempt(infl);
        true
    }

    /// Evict one running request: publish its exact mid-generation state
    /// as an internal session-cache entry (same slot invariant as
    /// [`Engine::retire`] — the state has consumed
    /// `prompt ++ generated[..n-1]`, and the last sampled token re-feeds
    /// at resume), release the slot, and requeue the request with its
    /// sampler/stream progress attached.  The client stream sees nothing:
    /// no terminal event, no latency sample — the continuation is seamless.
    fn preempt(&mut self, infl: InFlight) {
        let InFlight {
            mut req,
            slot,
            generated,
            first_token_at,
            last_token_at,
            sampler,
            stream,
            ..
        } = infl;
        let sid = PREEMPT_SID_TAG | req.id;
        let consumed = generated.len().saturating_sub(1);
        let mut toks = req.prompt.clone();
        toks.extend_from_slice(&generated[..consumed]);
        let cache = self.cache.as_ref().expect("preemption requires a cache");
        let st = self.pool.get(slot);
        cache.insert_session(sid, &req.variant, &toks, &st.conv, &st.ssm);
        self.pool.release(slot);
        self.metrics.note_finish_reason(FinishReason::Preempted);
        if let Some(t) = &self.trace {
            if t.sink.sampled(req.id) {
                t.sink.instant(
                    req.id,
                    "preempted",
                    vec![("generated", num(generated.len() as f64))],
                );
            }
        }
        if let Some(f) = &self.flight {
            f.record(
                req.id,
                FlightKind::Preempt,
                format!("generated={}", generated.len()),
            );
        }
        req.resume = Some(Box::new(ResumeState {
            generated,
            sampler,
            stream,
            first_token_at,
            last_token_at,
            snapshot_sid: sid,
        }));
        // the snapshot just published is the only copy of this request's
        // progress: pin it so queue-time cache pressure cannot evict it
        // before the resume (unpinned again when it leaves the queue)
        self.pin_queued(&req);
        insert_by_priority(&mut self.pending, req);
        self.metrics
            .note_queue_depth(self.pending.len() + self.active.len());
    }

    /// Re-admit a preempted request: rebuild its state (session-cache hit
    /// on the preemption snapshot → zero prefill; a cold miss re-prefills
    /// `prompt ++ generated[..n-1]` — slower, still exact for fp32),
    /// restore the saved sampler/stream, and continue decoding at the next
    /// position.  No FirstToken event, TTFT sample, or PromptTokens
    /// re-count — from the client's view this is the same in-flight
    /// request.
    fn admit_resumed(&mut self, mut req: Request, slot: usize) -> Result<()> {
        let resume = *req.resume.take().expect("resume state present");
        let submitted = req.submitted_at;
        // the state to rebuild has consumed prompt ++ generated[..n-1];
        // the final transcript token re-feeds through decode below
        let mut transcript = req.prompt.clone();
        transcript.extend_from_slice(&resume.generated);
        let plan_len = transcript.len() - 1;
        let (mut chunks, _) = full_bucket_plan(&self.prefill_buckets, plan_len);
        let mut offset = 0usize;
        if let Some(cache) = &self.cache {
            if let Some(s) =
                cache.lookup_session(resume.snapshot_sid, &req.variant, &transcript)
            {
                if self.pool.seed(slot, &s.conv, &s.ssm) {
                    offset = s.covered;
                    chunks = full_bucket_plan(&self.prefill_buckets, plan_len - s.covered).0;
                    self.metrics.count(Counter::CacheHits, 1);
                    self.metrics.count(Counter::CacheTokensSaved, offset as u64);
                }
            }
        }
        if let Some(t) = &self.trace {
            if t.sink.sampled(req.id) {
                t.sink.instant(
                    req.id,
                    "resumed",
                    vec![
                        ("slot", num(slot as f64)),
                        ("tokens_saved", num(offset as f64)),
                    ],
                );
            }
        }
        if let Some(f) = &self.flight {
            f.record(
                req.id,
                FlightKind::Resume,
                format!("slot={slot} tokens_saved={offset}"),
            );
        }
        let remainder = transcript.len() - offset - chunks.iter().sum::<usize>();
        for chunk_len in chunks {
            let toks: Vec<i32> = transcript[offset..offset + chunk_len]
                .iter()
                .map(|t| *t as i32)
                .collect();
            let st = self.pool.get(slot);
            let call_t0 = Instant::now();
            let out = self.be.prefill(&req.variant, &toks, &st.conv, &st.ssm)?;
            self.metrics.note_prefill_call(call_t0.elapsed().as_secs_f64());
            let stm = self.pool.get_mut(slot);
            stm.conv = out.conv_state;
            stm.ssm = out.ssm_state;
            offset += chunk_len;
            self.metrics.count(Counter::PrefillChunks, 1);
        }
        let mut last_logits: Option<Vec<f32>> = None;
        for i in 0..remainder {
            let tok = transcript[offset + i] as i32;
            let st = self.pool.get(slot);
            let call_t0 = Instant::now();
            let out = self.be.decode(&req.variant, 1, &st.conv, &st.ssm, &[tok])?;
            self.metrics.note_decode_call(call_t0.elapsed().as_secs_f64());
            let stm = self.pool.get_mut(slot);
            stm.conv = out.conv_state;
            stm.ssm = out.ssm_state;
            last_logits = Some(out.logits);
            self.metrics.count(Counter::DecodeSteps, 1);
            self.metrics.count(Counter::DecodeBatchSlots, 1);
        }
        let vocab = self.be.cfg().vocab_size;
        let mut sampler = resume.sampler;
        let mut generated = resume.generated;
        // position-keyed draws: sampling at position `generated.len()`
        // continues the exact sequence an unpreempted run would produce
        let tok =
            sampler.sample(&last_logits.expect("remainder >= 1")[..vocab], generated.len());
        sampler.observe(tok);
        let now = Instant::now();
        if let Some(prev) = resume.last_token_at {
            self.metrics
                .note_tpot(now.saturating_duration_since(prev).as_secs_f64());
        }
        generated.push(tok);
        let mut infl = InFlight {
            next_token: tok,
            slot,
            generated,
            submitted,
            first_token_at: resume.first_token_at,
            last_token_at: Some(now),
            sampler,
            stream: resume.stream,
            req,
        };
        let stopped_seq = infl.stream.push(&infl.req, tok);
        self.metrics.count(Counter::TokensGenerated, 1);
        if stopped_seq {
            self.retire(infl, FinishReason::StopSequence);
        } else if infl.req.stop_token == Some(tok) {
            self.retire(infl, FinishReason::StopToken);
        } else if infl.generated.len() >= infl.req.max_new_tokens {
            self.retire(infl, FinishReason::Length);
        } else {
            self.active.push(infl);
        }
        Ok(())
    }

    fn retire(&mut self, mut infl: InFlight, reason: FinishReason) {
        // a stop-sequence match withholds the matched tail from the
        // client; any other finish releases held-back partial-match tokens
        if reason != FinishReason::StopSequence {
            infl.stream.flush(&infl.req);
        }
        // session entries capture the end-of-turn state before the slot is
        // recycled.  The state has consumed prompt + generated[..n-1]: the
        // last sampled token was never fed back, so it is not part of the
        // state — the next turn's prompt (which repeats it) re-feeds it.
        if let (Some(cache), Some(sid)) = (&self.cache, infl.req.session_id) {
            let consumed = infl.generated.len().saturating_sub(1);
            let mut toks = infl.req.prompt.clone();
            toks.extend_from_slice(&infl.generated[..consumed]);
            let st = self.pool.get(infl.slot);
            cache.insert_session(sid, &infl.req.variant, &toks, &st.conv, &st.ssm);
        }
        self.pool.release(infl.slot);
        self.metrics.note_finish_reason(reason);
        self.metrics.count(Counter::RequestsCompleted, 1);
        self.metrics
            .note_latency(infl.submitted.elapsed().as_secs_f64());
        // client-visible output: full `generated` unless a stop sequence
        // withheld a tail (session-cache accounting above already used the
        // untruncated vector — the state really did consume those tokens)
        let mut generated = infl.generated;
        generated.truncate(infl.stream.visible());
        let fin = FinishedRequest {
            id: infl.req.id,
            prompt_len: infl.req.prompt.len(),
            generated,
            finish_reason: reason,
            ttft_s: infl
                .first_token_at
                .map(|t| (t - infl.submitted).as_secs_f64())
                .unwrap_or(0.0),
            total_s: infl.submitted.elapsed().as_secs_f64(),
            spec: None,
        };
        if let Some(t) = &self.trace {
            if t.sink.sampled(fin.id) {
                t.sink
                    .end_request(fin.id, &format!("{reason:?}"), fin.generated.len());
            }
        }
        if let Some(f) = &self.flight {
            f.record(
                fin.id,
                FlightKind::Finish,
                format!("{reason:?} tokens={}", fin.generated.len()),
            );
        }
        infl.req.emit(Event::Finished(fin.clone()));
        self.finished.push(fin);
    }

    /// Retire cancelled / past-deadline requests with the right
    /// [`FinishReason`].  Active requests go through the normal retire
    /// path — slot freed immediately, partial `generated` returned,
    /// state-cache session entry still published for resumable turns;
    /// still-pending requests finish with empty output and no slot churn.
    fn sweep_lifecycle(&mut self) {
        let mut i = 0;
        while i < self.pending.len() {
            if let Some(reason) = self.pending[i].lifecycle_reason() {
                let req = self.pending.remove(i).expect("index in bounds");
                self.unpin_queued(&req);
                finish_unadmitted(
                    &mut self.metrics,
                    self.trace.as_ref(),
                    self.flight.as_ref(),
                    &mut self.finished,
                    req,
                    reason,
                );
            } else {
                i += 1;
            }
        }
        let mut i = 0;
        while i < self.active.len() {
            if let Some(reason) = self.active[i].req.lifecycle_reason() {
                let infl = self.active.swap_remove(i);
                self.retire(infl, reason);
            } else {
                i += 1;
            }
        }
    }

    /// One batched decode step over all active sequences.
    fn decode_step(&mut self) -> Result<()> {
        if self.active.is_empty() {
            return Ok(());
        }
        // group by variant (different executables)
        let variants: Vec<String> = {
            let mut v: Vec<String> =
                self.active.iter().map(|a| a.req.variant.clone()).collect();
            v.sort();
            v.dedup();
            v
        };
        let vocab = self.be.cfg().vocab_size;
        let mut to_retire: Vec<(usize, FinishReason)> = Vec::new();

        for variant in variants {
            let idxs: Vec<usize> = self
                .active
                .iter()
                .enumerate()
                .filter(|(_, a)| a.req.variant == variant)
                .map(|(i, _)| i)
                .collect();
            for plan in self.batcher.plan(idxs.len()) {
                let members: Vec<usize> =
                    plan.members.iter().map(|m| idxs[*m]).collect();
                // gather states (pad by repeating the first member)
                let mut slot_ids: Vec<usize> =
                    members.iter().map(|i| self.active[*i].slot).collect();
                let mut tokens: Vec<i32> = members
                    .iter()
                    .map(|i| self.active[*i].next_token as i32)
                    .collect();
                for _ in 0..plan.padding {
                    slot_ids.push(slot_ids[0]);
                    tokens.push(tokens[0]);
                }
                let (conv, ssm) = self.pool.gather(&slot_ids);
                let call_t0 = Instant::now();
                let out = self.be.decode(&variant, plan.bucket, &conv, &ssm, &tokens)?;
                let call_s = call_t0.elapsed().as_secs_f64();
                self.metrics.note_decode_call(call_s);
                if let Some(t) = &self.trace {
                    t.sink.span_engine(
                        t.lane,
                        "decode_step",
                        call_s,
                        vec![
                            ("bucket", num(plan.bucket as f64)),
                            ("padding", num(plan.padding as f64)),
                        ],
                    );
                }
                // scatter only real members
                let real = members.len();
                let conv_len = conv.len() / plan.bucket;
                let ssm_len = ssm.len() / plan.bucket;
                self.pool.scatter(
                    &slot_ids[..real],
                    &out.conv_state[..real * conv_len],
                    &out.ssm_state[..real * ssm_len],
                );
                self.metrics.count(Counter::DecodeSteps, 1);
                self.metrics
                    .count(Counter::DecodePaddedSlots, plan.padding as u64);
                self.metrics
                    .count(Counter::DecodeBatchSlots, plan.bucket as u64);

                let now = Instant::now();
                for (b, &ai) in members.iter().enumerate() {
                    let logits = &out.logits[b * vocab..(b + 1) * vocab];
                    let infl = &mut self.active[ai];
                    let tok = infl.sampler.sample(logits, infl.generated.len());
                    infl.sampler.observe(tok);
                    infl.next_token = tok;
                    infl.generated.push(tok);
                    if let Some(prev) = infl.last_token_at.replace(now) {
                        self.metrics.note_tpot((now - prev).as_secs_f64());
                    }
                    let stopped_seq = infl.stream.push(&infl.req, tok);
                    self.metrics.count(Counter::TokensGenerated, 1);
                    if stopped_seq {
                        to_retire.push((ai, FinishReason::StopSequence));
                    } else if infl.req.stop_token == Some(tok) {
                        to_retire.push((ai, FinishReason::StopToken));
                    } else if infl.generated.len() >= infl.req.max_new_tokens {
                        to_retire.push((ai, FinishReason::Length));
                    }
                }
            }
        }
        to_retire.sort_unstable_by_key(|(ai, _)| *ai);
        for (ai, reason) in to_retire.into_iter().rev() {
            let infl = self.active.swap_remove(ai);
            self.retire(infl, reason);
        }
        Ok(())
    }

    /// Publish this engine's live request table into its telemetry status
    /// slot — the `/statusz` feed.  Re-published every step so the table
    /// reflects the engine's latest scheduling decisions; with no attached
    /// telemetry this is free.
    fn publish_status(&mut self) {
        let Some(tel) = self.metrics.telemetry() else { return };
        let now = Instant::now();
        let mut rows = Vec::with_capacity(self.pending.len() + self.active.len());
        for r in &self.pending {
            let tokens = r.resume.as_ref().map(|x| x.generated.len()).unwrap_or(0);
            rows.push(status_row(
                r,
                "pending",
                self.policy.effective_priority(r, now),
                tokens,
                now,
            ));
        }
        for a in &self.active {
            rows.push(status_row(
                &a.req,
                "active",
                a.req.priority as i64,
                a.generated.len(),
                now,
            ));
        }
        let status = Json::Obj(vec![
            ("pending".to_string(), num(self.pending.len() as f64)),
            ("active".to_string(), num(self.active.len() as f64)),
            ("max_queue".to_string(), num(self.policy.max_queue as f64)),
            ("requests".to_string(), Json::Arr(rows)),
        ]);
        tel.set_status(status);
    }

    /// One scheduler iteration: resolve cancellations/deadlines, admit,
    /// then decode.
    pub fn step(&mut self) -> Result<()> {
        self.sweep_lifecycle();
        let depth = self.pending.len() + self.active.len();
        self.metrics.note_queue_depth(depth);
        let t0 = Instant::now();
        self.admit()?;
        self.metrics.note_active_slots(self.active.len());
        let r = self.decode_step();
        if depth > 0 {
            // only steps that had work count toward utilization
            self.metrics.note_busy(t0.elapsed().as_secs_f64());
        }
        self.publish_status();
        r
    }

    /// Drive until every submitted request completes.
    pub fn run(&mut self) -> Result<()> {
        self.metrics.start();
        while !self.pending.is_empty() || !self.active.is_empty() {
            self.step()?;
        }
        self.metrics.stop();
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::NativeBackend;

    fn be() -> NativeBackend {
        NativeBackend::synthetic(3)
    }

    fn requests(vocab: usize, max_new: usize) -> Vec<Request> {
        // mixed lengths: single-token, sub-bucket, bucket-crossing
        let lens = [1usize, 5, 24, 33, 64, 100];
        lens.iter()
            .enumerate()
            .map(|(i, &plen)| {
                let prompt: Vec<u32> =
                    (0..plen).map(|j| ((i * 131 + j * 17) % vocab) as u32).collect();
                Request::new(i as u64, prompt, max_new, "fp32")
            })
            .collect()
    }

    #[test]
    fn chunk_plan_reserves_final_token() {
        let be = be();
        let eng = Engine::new(&be, EngineConfig::default());
        for plen in [1usize, 2, 31, 32, 33, 64, 100, 257] {
            let (chunks, rest) = eng.chunk_plan(plen);
            assert!(rest >= 1, "plen {plen}");
            assert_eq!(chunks.iter().sum::<usize>() + rest, plen, "plen {plen}");
            assert!(rest <= 32, "plen {plen}: remainder {rest} exceeds smallest bucket");
        }
    }

    #[test]
    fn engine_completes_mixed_trace_on_native_backend() {
        // the formerly artifact-gated end-to-end path, now unconditional
        let be = be();
        let vocab = be.cfg().vocab_size;
        let mut eng = Engine::new(&be, EngineConfig::default());
        let reqs = requests(vocab, 6);
        let n = reqs.len();
        for r in reqs {
            eng.submit(r);
        }
        eng.run().unwrap();
        assert_eq!(eng.finished.len(), n);
        assert_eq!(eng.metrics.requests_completed, n as u64);
        for f in &eng.finished {
            assert_eq!(f.generated.len(), 6, "req {}", f.id);
        }
        assert_eq!(eng.n_pending(), 0);
        assert_eq!(eng.n_active(), 0);
    }

    #[test]
    fn batched_decode_matches_one_at_a_time() {
        // packing sequences into decode batches must not change any output
        let be = be();
        let vocab = be.cfg().vocab_size;
        let run = |max_active: usize| -> Vec<(u64, Vec<u32>)> {
            let mut eng = Engine::new(
                &be,
                EngineConfig { max_active, greedy_chunking: true },
            );
            for r in requests(vocab, 8) {
                eng.submit(r);
            }
            eng.run().unwrap();
            let mut got: Vec<(u64, Vec<u32>)> =
                eng.finished.iter().map(|f| (f.id, f.generated.clone())).collect();
            got.sort();
            got
        };
        assert_eq!(run(1), run(8), "batching changed generated tokens");
    }

    #[test]
    fn engine_tracks_queue_depth_and_busy_time() {
        let be = be();
        let vocab = be.cfg().vocab_size;
        let mut eng = Engine::new(&be, EngineConfig::default());
        let reqs = requests(vocab, 4);
        let n = reqs.len();
        for r in reqs {
            eng.submit(r);
        }
        assert_eq!(eng.metrics.queue_depth_peak, n as u64);
        eng.run().unwrap();
        assert!(eng.metrics.busy_s > 0.0, "busy time accumulated");
        assert!(eng.metrics.utilization() > 0.0);
        assert!(eng.metrics.utilization() <= 1.0 + 1e-9);
    }

    #[test]
    fn max_active_bounds_concurrency() {
        let be = be();
        let vocab = be.cfg().vocab_size;
        let mut eng = Engine::new(&be, EngineConfig { max_active: 2, greedy_chunking: true });
        for r in requests(vocab, 12) {
            eng.submit(r);
        }
        let n = 6;
        while eng.n_pending() > 0 || eng.n_active() > 0 {
            eng.step().unwrap();
            assert!(eng.n_active() <= 2);
        }
        assert_eq!(eng.finished.len(), n);
    }

    #[test]
    fn cache_on_is_bit_identical_to_cache_off() {
        use crate::statecache::{CacheConfig, StateCache};
        // shared 70-token system prompt, mixed tails and variants: the
        // cache must change prefill work, never tokens
        let be = be();
        let vocab = be.cfg().vocab_size;
        let make_reqs = || -> Vec<Request> {
            let sys: Vec<u32> = (0..70).map(|j| ((j * 7 + 3) % vocab) as u32).collect();
            (0..6usize)
                .map(|i| {
                    let mut prompt = sys.clone();
                    prompt.extend((0..2 + i * 7).map(|j| ((i * 131 + j * 17) % vocab) as u32));
                    let variant = if i % 2 == 0 { "fp32" } else { "fastmamba" };
                    Request::new(i as u64, prompt, 4, variant)
                })
                .collect()
        };
        let run = |cache: Option<Arc<StateCache>>| -> (Vec<(u64, Vec<u32>)>, Metrics) {
            let mut eng = Engine::new(&be, EngineConfig::default());
            if let Some(c) = cache {
                eng = eng.with_cache(c);
            }
            for r in make_reqs() {
                eng.submit(r);
            }
            eng.run().unwrap();
            let mut got: Vec<(u64, Vec<u32>)> =
                eng.finished.iter().map(|f| (f.id, f.generated.clone())).collect();
            got.sort();
            (got, eng.metrics)
        };

        let (off, m_off) = run(None);
        assert_eq!(m_off.cache_hits + m_off.cache_misses, 0, "no cache, no probes");

        let cache = Arc::new(StateCache::new(CacheConfig::default()));
        let (on, m_on) = run(Some(Arc::clone(&cache)));
        assert_eq!(off, on, "state cache changed generated tokens");
        // sequential admission: the first request per variant misses, the
        // rest hit the shared 64-token boundary snapshot
        assert_eq!(m_on.cache_hits, 4, "{}", m_on.summary());
        assert_eq!(m_on.cache_misses, 2);
        assert_eq!(m_on.cache_tokens_saved, 4 * 64);
        assert!(m_on.summary().contains("cache_hit="), "{}", m_on.summary());

        // a second engine sharing the cache hits on every admission
        let (again, m2) = run(Some(Arc::clone(&cache)));
        assert_eq!(off, again);
        assert_eq!(m2.cache_hits, 6);
        assert_eq!(m2.cache_misses, 0);
        assert!(cache.stats().hits >= 10);
    }

    #[test]
    fn session_resume_skips_prefix_recompute() {
        use crate::statecache::{CacheConfig, StateCache};
        let be = be();
        let vocab = be.cfg().vocab_size;
        let cache = Arc::new(StateCache::new(CacheConfig::default()));
        let p1: Vec<u32> = (0..40).map(|j| ((j * 13 + 1) % vocab) as u32).collect();

        // turn 1
        let mut eng = Engine::new(&be, EngineConfig::default()).with_cache(Arc::clone(&cache));
        eng.submit(Request::new(0, p1.clone(), 6, "fp32").with_session(9));
        eng.run().unwrap();
        let gen1 = eng.finished[0].generated.clone();
        assert_eq!(gen1.len(), 6);

        // turn 2: the prompt replays the whole transcript plus new input
        let mut p2 = p1.clone();
        p2.extend_from_slice(&gen1);
        p2.extend((0..8).map(|j| ((j * 29 + 5) % vocab) as u32));

        let mut eng2 =
            Engine::new(&be, EngineConfig::default()).with_cache(Arc::clone(&cache));
        eng2.submit(Request::new(1, p2.clone(), 6, "fp32").with_session(9));
        eng2.run().unwrap();
        let gen2 = eng2.finished[0].generated.clone();
        // the end-of-turn state covered prompt + 5 consumed generated tokens
        assert_eq!(eng2.metrics.cache_hits, 1, "{}", eng2.metrics.summary());
        assert_eq!(eng2.metrics.cache_tokens_saved, (p1.len() + gen1.len() - 1) as u64);

        // resumed output matches serving the full turn-2 prompt from scratch
        // (fp32: chunking-invariant argmax, the conformance contract)
        let mut base = Engine::new(&be, EngineConfig::default());
        base.submit(Request::new(2, p2, 6, "fp32"));
        base.run().unwrap();
        assert_eq!(gen2, base.finished[0].generated, "session resume diverged");
    }

    #[test]
    fn stop_token_halts_generation() {
        let be = be();
        let vocab = be.cfg().vocab_size;
        let prompt: Vec<u32> = (0..33).map(|j| ((j * 13) % vocab) as u32).collect();
        // discover the greedy trace, then stop on its 3rd token
        let mut probe = Engine::new(&be, EngineConfig::default());
        probe.submit(Request::new(0, prompt.clone(), 8, "fp32"));
        probe.run().unwrap();
        assert_eq!(probe.finished[0].finish_reason, FinishReason::Length);
        let gen = probe.finished[0].generated.clone();
        let stop = gen[2];
        if gen[..2].contains(&stop) {
            return; // degenerate trace; stop position ambiguous
        }
        let mut eng = Engine::new(&be, EngineConfig::default());
        eng.submit(Request::new(0, prompt, 8, "fp32").with_stop_token(stop));
        eng.run().unwrap();
        let got = &eng.finished[0].generated;
        assert_eq!(got.last(), Some(&stop));
        assert_eq!(got.len(), 3, "must halt at the stop token, got {got:?}");
        assert_eq!(eng.finished[0].finish_reason, FinishReason::StopToken);
    }

    #[test]
    fn sampled_stream_same_seed_identical_different_seed_diverges() {
        use super::super::sampler::SamplingParams;
        // same seed + params => identical streams (and batching-invariant,
        // because draws are position-keyed); different seeds diverge
        let be = be();
        let vocab = be.cfg().vocab_size;
        let run = |seed: u64, max_active: usize| -> Vec<(u64, Vec<u32>)> {
            let mut eng =
                Engine::new(&be, EngineConfig { max_active, greedy_chunking: true });
            for r in requests(vocab, 8) {
                let sp = SamplingParams {
                    temperature: 1.0,
                    seed: seed.wrapping_add(r.id),
                    ..SamplingParams::default()
                };
                eng.submit(r.with_sampling(sp));
            }
            eng.run().unwrap();
            let mut got: Vec<(u64, Vec<u32>)> =
                eng.finished.iter().map(|f| (f.id, f.generated.clone())).collect();
            got.sort();
            got
        };
        let a = run(500, 8);
        assert_eq!(a, run(500, 8), "same seed must reproduce the stream");
        assert_eq!(a, run(500, 1), "sampling must be batching-invariant");
        assert_ne!(a, run(501, 8), "different seeds must diverge");
    }

    #[test]
    fn stop_sequence_halts_engine_and_withholds_match() {
        use super::super::sampler::SamplingParams;
        // discover the greedy trace, then stop on the rendered text of its
        // 2nd+3rd tokens — a sequence spanning a token boundary
        let be = be();
        let vocab = be.cfg().vocab_size;
        let prompt: Vec<u32> = (0..33).map(|j| ((j * 13) % vocab) as u32).collect();
        let mut probe = Engine::new(&be, EngineConfig::default());
        probe.submit(Request::new(0, prompt.clone(), 8, "fp32"));
        probe.run().unwrap();
        let gen = probe.finished[0].generated.clone();
        let stop = format!("{} {}", gen[1], gen[2]);
        let mut eng = Engine::new(&be, EngineConfig::default());
        let sp = SamplingParams {
            stop_sequences: vec![stop.clone()],
            ..SamplingParams::default()
        };
        let h = eng.submit(Request::new(0, prompt, 8, "fp32").with_sampling(sp));
        eng.run().unwrap();
        let fin = &eng.finished[0];
        assert_eq!(fin.finish_reason, FinishReason::StopSequence);
        // the visible output is a strict prefix of the greedy trace whose
        // rendering does not contain the stop text (the match — wherever
        // the substring first lands — is withheld)
        assert!(fin.generated.len() < gen.len());
        assert_eq!(fin.generated, gen[..fin.generated.len()]);
        let rendered = fin
            .generated
            .iter()
            .map(|t| t.to_string())
            .collect::<Vec<_>>()
            .join(" ");
        assert!(
            !rendered.contains(&stop),
            "visible stream {rendered:?} must not contain stop {stop:?}"
        );
        // the streamed events agree with the truncated batch output
        let (first, toks, fin_ev) = drain(&h);
        assert!(first);
        assert_eq!(toks, fin.generated);
        assert_eq!(fin_ev.unwrap().finish_reason, FinishReason::StopSequence);
    }

    /// Drain a handle's buffered events into (saw_first, tokens, terminal).
    fn drain(h: &SubmitHandle) -> (bool, Vec<u32>, Option<FinishedRequest>) {
        let mut first = false;
        let mut toks = Vec::new();
        let mut fin = None;
        while let Some(ev) = h.try_event() {
            match ev {
                Event::FirstToken => {
                    assert!(!first, "FirstToken emitted twice");
                    assert!(toks.is_empty(), "FirstToken must precede Token 0");
                    first = true;
                }
                Event::Token { tok, index } => {
                    assert_eq!(index, toks.len(), "token indexes must be contiguous");
                    toks.push(tok);
                }
                Event::Finished(f) => {
                    assert!(fin.is_none(), "Finished emitted twice");
                    fin = Some(f);
                }
            }
        }
        (first, toks, fin)
    }

    #[test]
    fn streamed_events_match_batch_output_for_all_variants() {
        use crate::model::Variant;
        // the acceptance contract: the streamed token sequence is
        // bit-identical to the batch FinishedRequest for every variant
        let be = be();
        let vocab = be.cfg().vocab_size;
        let mut eng = Engine::new(&be, EngineConfig::default());
        let mut handles = Vec::new();
        for (i, v) in Variant::ALL.iter().enumerate() {
            let plen = 9 + 13 * i;
            let prompt: Vec<u32> =
                (0..plen).map(|j| ((i * 131 + j * 17) % vocab) as u32).collect();
            handles.push(eng.submit(Request::new(i as u64, prompt, 5, v.name())));
        }
        eng.run().unwrap();
        assert_eq!(eng.finished.len(), Variant::ALL.len());
        for h in &handles {
            let want = eng.finished.iter().find(|f| f.id == h.id()).unwrap();
            let (first, toks, fin) = drain(h);
            assert!(first, "req {}", h.id());
            assert_eq!(toks, want.generated, "req {}: stream != batch output", h.id());
            let fin = fin.expect("terminal event");
            assert_eq!(fin.generated, want.generated);
            assert_eq!(fin.finish_reason, FinishReason::Length);
        }
    }

    #[test]
    fn cancel_mid_generation_frees_slot_and_keeps_greedy_prefix() {
        let be = be();
        let vocab = be.cfg().vocab_size;
        let prompt: Vec<u32> = (0..33).map(|j| ((j * 13) % vocab) as u32).collect();
        // reference greedy trace
        let mut probe = Engine::new(&be, EngineConfig::default());
        probe.submit(Request::new(9, prompt.clone(), 24, "fp32"));
        probe.run().unwrap();
        let want = probe.finished[0].generated.clone();
        assert_eq!(want.len(), 24);

        // one-slot engine: a long request holds the slot, a short one queues
        let mut eng = Engine::new(&be, EngineConfig { max_active: 1, greedy_chunking: true });
        let long = eng.submit(Request::new(0, prompt.clone(), 24, "fp32"));
        let short = eng.submit(Request::new(1, prompt.clone(), 3, "fp32"));
        let mut streamed = 0usize;
        while streamed < 4 {
            eng.step().unwrap();
            while let Some(ev) = long.try_event() {
                if matches!(ev, Event::Token { .. }) {
                    streamed += 1;
                }
            }
            assert_eq!(eng.n_active(), 1, "short request must wait on capacity");
        }
        long.cancel();
        eng.run().unwrap(); // sweeps the cancel, then serves the queued request

        let long_fin = eng.finished.iter().find(|f| f.id == 0).unwrap();
        let short_fin = eng.finished.iter().find(|f| f.id == 1).unwrap();
        assert_eq!(long_fin.finish_reason, FinishReason::Cancelled);
        let n = long_fin.generated.len();
        assert!(n >= 4 && n < 24, "partial output expected, got {n}");
        assert_eq!(long_fin.generated[..], want[..n], "partial != greedy prefix");
        // the freed slot let the queued request run to completion
        assert_eq!(short_fin.finish_reason, FinishReason::Length);
        assert_eq!(short_fin.generated[..], want[..3]);
        assert_eq!(eng.metrics.cancelled_requests, 1);
        // both handles saw their terminal events
        let (_, _, fin) = drain(&long);
        assert_eq!(fin.expect("terminal").finish_reason, FinishReason::Cancelled);
        let (_, _, fin) = drain(&short);
        assert_eq!(fin.expect("terminal").finish_reason, FinishReason::Length);
    }

    #[test]
    fn deadline_expiry_reports_reason() {
        use std::time::Duration;
        let be = be();
        let vocab = be.cfg().vocab_size;
        let prompt: Vec<u32> = (0..24).map(|j| ((j * 7) % vocab) as u32).collect();
        // already expired at the first step: retired from pending, empty
        let mut eng = Engine::new(&be, EngineConfig::default());
        let h = eng
            .submit(Request::new(0, prompt.clone(), 8, "fp32").with_deadline(Duration::ZERO));
        eng.run().unwrap();
        assert_eq!(eng.finished[0].finish_reason, FinishReason::Deadline);
        assert!(eng.finished[0].generated.is_empty());
        assert_eq!(eng.metrics.deadline_expired, 1);
        let (_, _, fin) = drain(&h);
        assert_eq!(fin.expect("terminal").finish_reason, FinishReason::Deadline);

        // expires mid-generation: partial output, same reason
        let mut eng = Engine::new(&be, EngineConfig::default());
        eng.submit(
            Request::new(1, prompt, 100_000, "fp32")
                .with_deadline(Duration::from_millis(15)),
        );
        while eng.n_pending() > 0 || eng.n_active() > 0 {
            eng.step().unwrap();
            std::thread::sleep(Duration::from_millis(4));
        }
        let f = &eng.finished[0];
        assert_eq!(f.finish_reason, FinishReason::Deadline);
        assert!(f.generated.len() < 100_000);
    }

    #[test]
    fn priority_admits_high_before_fifo() {
        let be = be();
        let vocab = be.cfg().vocab_size;
        let prompt: Vec<u32> = (0..9).map(|j| ((j * 5) % vocab) as u32).collect();
        let mut eng = Engine::new(&be, EngineConfig { max_active: 1, greedy_chunking: true });
        eng.submit(Request::new(0, prompt.clone(), 2, "fp32"));
        eng.submit(Request::new(1, prompt.clone(), 2, "fp32"));
        eng.submit(Request::new(2, prompt, 2, "fp32").with_priority(5));
        eng.run().unwrap();
        let order: Vec<u64> = eng.finished.iter().map(|f| f.id).collect();
        assert_eq!(order, vec![2, 0, 1], "higher priority first, FIFO within a level");
    }

    #[test]
    fn cancelled_request_still_publishes_session_entry() {
        use crate::statecache::{CacheConfig, StateCache};
        // abandoning a turn must not lose the conversation: the partial
        // end-of-turn state is published so the next turn still resumes
        let be = be();
        let vocab = be.cfg().vocab_size;
        let cache = Arc::new(StateCache::new(CacheConfig::default()));
        let p1: Vec<u32> = (0..40).map(|j| ((j * 13 + 1) % vocab) as u32).collect();

        let mut eng =
            Engine::new(&be, EngineConfig::default()).with_cache(Arc::clone(&cache));
        let h = eng.submit(Request::new(0, p1.clone(), 24, "fp32").with_session(77));
        let mut streamed = 0usize;
        while streamed < 3 {
            eng.step().unwrap();
            while let Some(ev) = h.try_event() {
                if matches!(ev, Event::Token { .. }) {
                    streamed += 1;
                }
            }
        }
        h.cancel();
        eng.run().unwrap();
        let gen1 = eng.finished[0].generated.clone();
        assert_eq!(eng.finished[0].finish_reason, FinishReason::Cancelled);
        assert!(!gen1.is_empty());

        // turn 2 extends the partial transcript and resumes from the
        // cancelled turn's session entry
        let mut p2 = p1.clone();
        p2.extend_from_slice(&gen1);
        p2.extend((0..5).map(|j| ((j * 29 + 3) % vocab) as u32));
        let mut eng2 =
            Engine::new(&be, EngineConfig::default()).with_cache(Arc::clone(&cache));
        eng2.submit(Request::new(1, p2.clone(), 4, "fp32").with_session(77));
        eng2.run().unwrap();
        assert_eq!(eng2.metrics.cache_hits, 1, "{}", eng2.metrics.summary());
        assert_eq!(
            eng2.metrics.cache_tokens_saved,
            (p1.len() + gen1.len() - 1) as u64
        );
        // resumed output matches serving the full prompt from scratch
        let mut base = Engine::new(&be, EngineConfig::default());
        base.submit(Request::new(2, p2, 4, "fp32"));
        base.run().unwrap();
        assert_eq!(eng2.finished[0].generated, base.finished[0].generated);
    }

    #[test]
    fn trace_spans_are_balanced_with_one_retire_per_request() {
        use std::time::Duration;
        // every request lane must be a well-formed envelope: one B at
        // enqueue, one E at retire carrying the terminal reason — including
        // the Cancelled and Deadline exits, which never reach decode
        let be = be();
        let vocab = be.cfg().vocab_size;
        let sink = Arc::new(TraceSink::new(1));
        let mut eng =
            Engine::new(&be, EngineConfig { max_active: 1, greedy_chunking: true })
                .with_trace(Arc::clone(&sink), 0);
        let prompt: Vec<u32> = (0..33).map(|j| ((j * 13) % vocab) as u32).collect();
        let long = eng.submit(Request::new(0, prompt.clone(), 24, "fp32"));
        eng.submit(Request::new(1, prompt.clone(), 3, "fp32"));
        eng.submit(Request::new(2, prompt, 4, "fp32").with_deadline(Duration::ZERO));
        let mut streamed = 0usize;
        while streamed < 4 {
            eng.step().unwrap();
            while let Some(ev) = long.try_event() {
                if matches!(ev, Event::Token { .. }) {
                    streamed += 1;
                }
            }
        }
        long.cancel();
        eng.run().unwrap();
        assert_eq!(eng.finished.len(), 3);

        let doc = sink.to_chrome_json();
        let events = doc.arr_field("traceEvents").unwrap();
        assert!(!events.is_empty());
        for f in &eng.finished {
            let lane: Vec<&Json> = events
                .iter()
                .filter(|e| {
                    e.usize_field("pid").unwrap() == 0
                        && e.usize_field("tid").unwrap() as u64 == f.id
                })
                .collect();
            assert!(!lane.is_empty(), "req {}: no trace events", f.id);
            // balanced B/E envelope: depth never negative, closes at zero
            let mut depth = 0i64;
            let mut ends = 0usize;
            for e in &lane {
                match e.str_field("ph").unwrap() {
                    "B" => depth += 1,
                    "E" => {
                        depth -= 1;
                        ends += 1;
                    }
                    _ => {}
                }
                assert!(depth >= 0, "req {}: E before B", f.id);
            }
            assert_eq!(depth, 0, "req {}: unbalanced envelope", f.id);
            assert_eq!(ends, 1, "req {}: exactly one retire", f.id);
            // timestamps monotone in record order ('X' spans back-date
            // their start and are exempt)
            let mut prev = f64::NEG_INFINITY;
            for e in &lane {
                if e.str_field("ph").unwrap() == "X" {
                    continue;
                }
                let ts = e.get("ts").unwrap().as_f64().unwrap();
                assert!(ts >= prev, "req {}: timestamps went backwards", f.id);
                prev = ts;
            }
            // the retire carries the terminal reason and token count
            let end = lane
                .iter()
                .find(|e| e.str_field("ph").unwrap() == "E")
                .unwrap();
            let args = end.get("args").expect("retire args");
            assert_eq!(
                args.str_field("finish_reason").unwrap(),
                format!("{:?}", f.finish_reason),
                "req {}",
                f.id
            );
            assert_eq!(args.usize_field("generated").unwrap(), f.generated.len());
        }
        // the reasons this trace must cover
        let reasons: Vec<FinishReason> =
            eng.finished.iter().map(|f| f.finish_reason).collect();
        assert!(reasons.contains(&FinishReason::Cancelled));
        assert!(reasons.contains(&FinishReason::Deadline));
        assert!(reasons.contains(&FinishReason::Length));
        // batch-level decode spans landed in the engine's own lane (pid 1)
        assert!(
            events.iter().any(|e| e.usize_field("pid").unwrap() == 1
                && e.str_field("ph").unwrap() == "X"),
            "no engine-lane decode spans"
        );
    }

    #[test]
    fn telemetry_snapshot_matches_legacy_summary_across_variants() {
        use crate::model::Variant;
        // the write-through contract: a snapshot rebuilt from the live
        // telemetry cells alone equals the engine's own Metrics, for a
        // workload spanning every quantization variant
        let be = be();
        let vocab = be.cfg().vocab_size;
        let tel = Arc::new(Telemetry::new());
        let mut eng =
            Engine::new(&be, EngineConfig::default()).with_telemetry(Arc::clone(&tel));
        for (i, v) in Variant::ALL.iter().enumerate() {
            let plen = 9 + 13 * i;
            let prompt: Vec<u32> =
                (0..plen).map(|j| ((i * 131 + j * 17) % vocab) as u32).collect();
            eng.submit(Request::new(i as u64, prompt, 5, v.name()));
        }
        eng.run().unwrap();
        let m = &eng.metrics;
        assert_eq!(m.requests_completed, Variant::ALL.len() as u64);

        let snap = Metrics::from_telemetry(&tel);
        assert_eq!(snap.requests_completed, m.requests_completed);
        assert_eq!(snap.tokens_generated, m.tokens_generated);
        assert_eq!(snap.prompt_tokens, m.prompt_tokens);
        assert_eq!(snap.prefill_chunks, m.prefill_chunks);
        assert_eq!(snap.decode_steps, m.decode_steps);
        assert_eq!(snap.decode_batch_slots, m.decode_batch_slots);
        assert_eq!(snap.decode_padded_slots, m.decode_padded_slots);
        assert_eq!(snap.cache_hits, m.cache_hits);
        assert_eq!(snap.cache_misses, m.cache_misses);
        assert_eq!(snap.cache_tokens_saved, m.cache_tokens_saved);
        assert_eq!(snap.cancelled_requests, m.cancelled_requests);
        assert_eq!(snap.deadline_expired, m.deadline_expired);
        assert_eq!(snap.queue_depth_peak, m.queue_depth_peak);
        // histograms carry identical observation counts and sums
        assert_eq!(snap.ttft.count(), m.ttft.count());
        assert_eq!(snap.latency.count(), m.latency.count());
        assert_eq!(snap.prefill_call.count(), m.prefill_call.count());
        assert_eq!(snap.decode_call.count(), m.decode_call.count());
        assert_eq!(snap.tpot.count(), m.tpot.count());
        assert_eq!(snap.latency.count(), m.requests_completed);
        // busy time round-trips through integer microseconds
        assert!((snap.busy_s - m.busy_s).abs() < 1e-2, "{} vs {}", snap.busy_s, m.busy_s);
    }

    #[test]
    fn aging_promotes_starved_low_priority_over_steady_high_stream() {
        use std::time::Duration;
        // a low-priority request that has waited 10s must overtake fresh
        // high-priority arrivals once its aged effective priority clears
        // theirs — and must not without aging
        let be = be();
        let vocab = be.cfg().vocab_size;
        let prompt: Vec<u32> = (0..9).map(|j| ((j * 5) % vocab) as u32).collect();
        let run = |age_rate: f64| -> (Vec<u64>, u64) {
            let mut eng =
                Engine::new(&be, EngineConfig { max_active: 1, greedy_chunking: true })
                    .with_policy(SchedPolicy { age_rate, ..SchedPolicy::default() });
            let mut low = Request::new(0, prompt.clone(), 2, "fp32");
            low.submitted_at = low
                .submitted_at
                .checked_sub(Duration::from_secs(10))
                .expect("backdate submitted_at");
            eng.submit(low);
            eng.submit(Request::new(1, prompt.clone(), 2, "fp32").with_priority(5));
            eng.submit(Request::new(2, prompt.clone(), 2, "fp32").with_priority(5));
            eng.run().unwrap();
            (
                eng.finished.iter().map(|f| f.id).collect(),
                eng.metrics.aging_reorders,
            )
        };
        let (off, off_reorders) = run(0.0);
        assert_eq!(off, vec![1, 2, 0], "no aging: strict priority order");
        assert_eq!(off_reorders, 0);
        let (on, on_reorders) = run(1.0);
        // 0 + 10s * 1/s = 10 > 5; the two high-priority requests stay FIFO
        assert_eq!(on, vec![0, 1, 2], "aged request must run first");
        assert!(on_reorders >= 1, "reorder must be counted");
    }

    #[test]
    fn preempt_resumes_token_exact_with_seamless_stream() {
        use crate::statecache::{CacheConfig, StateCache};
        // a high-priority arrival evicts the running request; the victim
        // later resumes from its snapshot and its full output — batch and
        // streamed — is identical to an undisturbed greedy run
        let be = be();
        let vocab = be.cfg().vocab_size;
        let prompt: Vec<u32> = (0..33).map(|j| ((j * 13) % vocab) as u32).collect();
        let hi_prompt: Vec<u32> = (0..9).map(|j| ((j * 7 + 2) % vocab) as u32).collect();
        let mut probe = Engine::new(&be, EngineConfig::default());
        probe.submit(Request::new(9, prompt.clone(), 16, "fp32"));
        probe.run().unwrap();
        let want = probe.finished[0].generated.clone();
        assert_eq!(want.len(), 16);

        let cache = Arc::new(StateCache::new(CacheConfig::default()));
        let mut eng =
            Engine::new(&be, EngineConfig { max_active: 1, greedy_chunking: true })
                .with_cache(Arc::clone(&cache))
                .with_policy(SchedPolicy {
                    preempt_threshold: Some(5),
                    ..SchedPolicy::default()
                });
        let v = eng.submit(Request::new(0, prompt.clone(), 16, "fp32"));
        let mut streamed = 0usize;
        while streamed < 4 {
            eng.step().unwrap();
            while let Some(ev) = v.try_event() {
                if matches!(ev, Event::Token { .. }) {
                    streamed += 1;
                }
            }
        }
        let hi = eng.submit(Request::new(1, hi_prompt, 2, "fp32").with_priority(9));
        eng.run().unwrap();

        assert_eq!(eng.metrics.preempted_requests, 1, "{}", eng.metrics.summary());
        // the resume was a session-cache hit on the preemption snapshot,
        // which covered prompt ++ generated[..n-1]
        assert_eq!(eng.metrics.cache_hits, 1, "{}", eng.metrics.summary());
        assert_eq!(
            eng.metrics.cache_tokens_saved,
            (prompt.len() + streamed - 1) as u64
        );
        // the preemptor ran first on the freed slot
        let order: Vec<u64> = eng.finished.iter().map(|f| f.id).collect();
        assert_eq!(order, vec![1, 0]);
        let v_fin = eng.finished.iter().find(|f| f.id == 0).unwrap();
        assert_eq!(v_fin.finish_reason, FinishReason::Length);
        assert_eq!(v_fin.generated, want, "preemption changed the output");
        // the client stream is seamless: one FirstToken, contiguous token
        // indexes across the preemption, one terminal event
        let (first, toks, fin) = drain(&v);
        assert!(first);
        assert_eq!(toks, want);
        assert_eq!(fin.expect("terminal").finish_reason, FinishReason::Length);
        let (_, _, hi_fin) = drain(&hi);
        assert_eq!(hi_fin.expect("terminal").finish_reason, FinishReason::Length);
        // a preemption is not a completion: both requests retired exactly
        // once, each with one latency sample
        assert_eq!(eng.metrics.requests_completed, 2);
        assert_eq!(eng.metrics.latency.count(), 2);
        assert!(eng.metrics.summary().contains("preempted=1"), "{}", eng.metrics.summary());
    }

    #[test]
    fn preempt_sampled_stream_is_bit_exact_across_preemption() {
        use super::super::sampler::SamplingParams;
        use crate::statecache::{CacheConfig, StateCache};
        // position-keyed draws + carried sampler state: a preempted sampled
        // stream continues the exact sequence of an undisturbed run
        let be = be();
        let vocab = be.cfg().vocab_size;
        let prompt: Vec<u32> = (0..33).map(|j| ((j * 13) % vocab) as u32).collect();
        let hi_prompt: Vec<u32> = (0..9).map(|j| ((j * 7 + 2) % vocab) as u32).collect();
        let sp = SamplingParams { temperature: 1.0, seed: 1234, ..SamplingParams::default() };
        let mut probe = Engine::new(&be, EngineConfig::default());
        probe.submit(Request::new(9, prompt.clone(), 16, "fp32").with_sampling(sp.clone()));
        probe.run().unwrap();
        let want = probe.finished[0].generated.clone();

        let cache = Arc::new(StateCache::new(CacheConfig::default()));
        let mut eng =
            Engine::new(&be, EngineConfig { max_active: 1, greedy_chunking: true })
                .with_cache(Arc::clone(&cache))
                .with_policy(SchedPolicy {
                    preempt_threshold: Some(5),
                    ..SchedPolicy::default()
                });
        let v = eng.submit(Request::new(0, prompt, 16, "fp32").with_sampling(sp));
        let mut streamed = 0usize;
        while streamed < 4 {
            eng.step().unwrap();
            while let Some(ev) = v.try_event() {
                if matches!(ev, Event::Token { .. }) {
                    streamed += 1;
                }
            }
        }
        eng.submit(Request::new(1, hi_prompt, 2, "fp32").with_priority(9));
        eng.run().unwrap();
        assert_eq!(eng.metrics.preempted_requests, 1);
        let v_fin = eng.finished.iter().find(|f| f.id == 0).unwrap();
        assert_eq!(v_fin.generated, want, "sampled stream diverged across preemption");
    }

    #[test]
    fn preempt_snapshot_pinned_survives_cache_pressure() {
        use crate::statecache::{CacheConfig, StateCache};
        // regression for admission-aware eviction: while a preempted
        // request waits in the queue, enough cache traffic lands to evict
        // the whole LRU several times over — but its pinned snapshot must
        // survive, so the resume is still a session hit (and the output
        // still bit-exact with an undisturbed run)
        let be = be();
        let vocab = be.cfg().vocab_size;
        let prompt: Vec<u32> = (0..33).map(|j| ((j * 13) % vocab) as u32).collect();
        let hi_prompt: Vec<u32> = (0..9).map(|j| ((j * 7 + 2) % vocab) as u32).collect();
        let mut probe = Engine::new(&be, EngineConfig::default());
        probe.submit(Request::new(9, prompt.clone(), 16, "fp32"));
        probe.run().unwrap();
        let want = probe.finished[0].generated.clone();

        // one shard, 1 MiB: small enough to churn completely, large
        // enough to hold the preemption snapshot
        let cache =
            Arc::new(StateCache::new(CacheConfig { max_bytes: 1 << 20, shards: 1 }));
        let mut eng =
            Engine::new(&be, EngineConfig { max_active: 1, greedy_chunking: true })
                .with_cache(Arc::clone(&cache))
                .with_policy(SchedPolicy {
                    preempt_threshold: Some(5),
                    ..SchedPolicy::default()
                });
        let v = eng.submit(Request::new(0, prompt.clone(), 16, "fp32"));
        let mut streamed = 0usize;
        while streamed < 4 {
            eng.step().unwrap();
            while let Some(ev) = v.try_event() {
                if matches!(ev, Event::Token { .. }) {
                    streamed += 1;
                }
            }
        }
        eng.submit(Request::new(1, hi_prompt, 2, "fp32").with_priority(9));
        while eng.metrics.preempted_requests == 0 {
            eng.step().unwrap();
        }
        // forced pressure: several budgets' worth of foreign inserts while
        // the victim (the cache's least-recently-used entry) waits pinned
        let big = vec![0.5f32; 4096]; // 32 KiB per entry
        for i in 0..100u64 {
            cache.insert_session(1000 + i, "fp32", &[1, 2, 3], &big, &big);
        }
        assert!(cache.stats().evictions > 0, "pressure must actually evict");
        eng.run().unwrap();

        // the resume found the pinned snapshot
        assert_eq!(eng.metrics.cache_hits, 1, "{}", eng.metrics.summary());
        assert_eq!(
            eng.metrics.cache_tokens_saved,
            (prompt.len() + streamed - 1) as u64
        );
        let v_fin = eng.finished.iter().find(|f| f.id == 0).unwrap();
        assert_eq!(v_fin.generated, want, "pressured preemption changed the output");
        let (first, toks, fin) = drain(&v);
        assert!(first);
        assert_eq!(toks, want);
        assert_eq!(fin.expect("terminal").finish_reason, FinishReason::Length);
    }

    #[test]
    fn overload_shed_returns_overloaded_and_retry_succeeds() {
        // a full pending queue sheds the arrival synchronously with a
        // retriable terminal event; the shed request never pollutes the
        // latency histogram, and a later retry completes normally
        let be = be();
        let vocab = be.cfg().vocab_size;
        let prompt: Vec<u32> = (0..9).map(|j| ((j * 5) % vocab) as u32).collect();
        let mut eng =
            Engine::new(&be, EngineConfig { max_active: 1, greedy_chunking: true })
                .with_policy(SchedPolicy { max_queue: 2, ..SchedPolicy::default() });
        eng.submit(Request::new(0, prompt.clone(), 2, "fp32"));
        eng.submit(Request::new(1, prompt.clone(), 2, "fp32"));
        let shed = eng.submit(Request::new(2, prompt.clone(), 2, "fp32"));
        // the shed decision is synchronous at submit
        let (first, toks, fin) = drain(&shed);
        assert!(!first, "a shed request must not see FirstToken");
        assert!(toks.is_empty());
        let fin = fin.expect("synchronous terminal event");
        assert_eq!(fin.finish_reason, FinishReason::Overloaded);
        assert!(fin.generated.is_empty());
        assert_eq!(eng.metrics.requests_shed, 1);
        assert_eq!(eng.metrics.requests_dropped, 0, "sheds are not drops");
        eng.run().unwrap();
        // the retry lands in a drained queue and completes
        let retry = eng.submit(Request::new(3, prompt, 2, "fp32"));
        eng.run().unwrap();
        let (_, _, fin) = drain(&retry);
        assert_eq!(fin.expect("terminal").finish_reason, FinishReason::Length);
        // zero requests lost: every submit reached a terminal event
        assert_eq!(eng.metrics.requests_completed, 4);
        assert_eq!(eng.finished.len(), 4);
        // the latency histogram holds completed requests only
        assert_eq!(eng.metrics.latency.count(), 3);
        assert!(eng.metrics.summary().contains("shed=1"), "{}", eng.metrics.summary());
    }

    #[test]
    fn trace_covers_preempt_resume_and_shed_instants() {
        use crate::obs::{FlightKind, FlightRecorder};
        use crate::statecache::{CacheConfig, StateCache};
        // overload-path instants: a preempted-and-resumed request's lane
        // carries "preempted" and "resumed" instants inside a balanced
        // B/E envelope, and a shed arrival's lane carries "shed" before
        // its terminal E.  The flight recorder sees the same transitions.
        let be = be();
        let vocab = be.cfg().vocab_size;
        let prompt: Vec<u32> = (0..33).map(|j| ((j * 13) % vocab) as u32).collect();
        let hi_prompt: Vec<u32> = (0..9).map(|j| ((j * 7 + 2) % vocab) as u32).collect();
        let sink = Arc::new(TraceSink::new(1));
        let flight = Arc::new(FlightRecorder::with_capacity(256));
        let cache = Arc::new(StateCache::new(CacheConfig::default()));
        let mut eng =
            Engine::new(&be, EngineConfig { max_active: 1, greedy_chunking: true })
                .with_cache(Arc::clone(&cache))
                .with_trace(Arc::clone(&sink), 0)
                .with_flight(Arc::clone(&flight), 0)
                .with_policy(SchedPolicy {
                    preempt_threshold: Some(5),
                    max_queue: 2,
                    ..SchedPolicy::default()
                });
        let v = eng.submit(Request::new(0, prompt.clone(), 16, "fp32"));
        let mut streamed = 0usize;
        while streamed < 4 {
            eng.step().unwrap();
            while let Some(ev) = v.try_event() {
                if matches!(ev, Event::Token { .. }) {
                    streamed += 1;
                }
            }
        }
        // the preemptor, then two more arrivals; the second finds the
        // pending queue at max_queue=2 and is shed synchronously
        eng.submit(Request::new(1, hi_prompt, 2, "fp32").with_priority(9));
        eng.submit(Request::new(2, prompt.clone(), 2, "fp32"));
        let shed = eng.submit(Request::new(3, prompt.clone(), 2, "fp32"));
        let (_, _, fin) = drain(&shed);
        assert_eq!(fin.expect("terminal").finish_reason, FinishReason::Overloaded);
        eng.run().unwrap();
        assert_eq!(eng.metrics.preempted_requests, 1, "{}", eng.metrics.summary());
        assert_eq!(eng.metrics.requests_shed, 1);

        let doc = sink.to_chrome_json();
        let events = doc.arr_field("traceEvents").unwrap();
        let lane = |id: u64| -> Vec<&Json> {
            events
                .iter()
                .filter(|e| {
                    e.usize_field("pid").unwrap() == 0
                        && e.usize_field("tid").unwrap() as u64 == id
                })
                .collect()
        };
        // the victim's lane: balanced envelope containing both instants
        let victim = lane(0);
        let names: Vec<&str> = victim
            .iter()
            .filter(|e| e.str_field("ph").unwrap() == "i")
            .map(|e| e.str_field("name").unwrap())
            .collect();
        assert!(names.contains(&"preempted"), "victim instants: {names:?}");
        assert!(names.contains(&"resumed"), "victim instants: {names:?}");
        let pre = names.iter().position(|n| *n == "preempted").unwrap();
        let res = names.iter().position(|n| *n == "resumed").unwrap();
        assert!(pre < res, "preempted must precede resumed");
        let mut depth = 0i64;
        for e in &victim {
            match e.str_field("ph").unwrap() {
                "B" => depth += 1,
                "E" => depth -= 1,
                _ => {}
            }
            assert!(depth >= 0, "victim: E before B");
        }
        assert_eq!(depth, 0, "victim: unbalanced envelope");
        // the shed request's lane: one "shed" instant, then the terminal E
        let shed_lane = lane(3);
        let shed_names: Vec<&str> = shed_lane
            .iter()
            .filter(|e| e.str_field("ph").unwrap() == "i")
            .map(|e| e.str_field("name").unwrap())
            .collect();
        assert!(shed_names.contains(&"shed"), "shed instants: {shed_names:?}");
        let end = shed_lane
            .iter()
            .find(|e| e.str_field("ph").unwrap() == "E")
            .expect("shed request's terminal E");
        assert_eq!(
            end.get("args").unwrap().str_field("finish_reason").unwrap(),
            "Overloaded"
        );
        // the flight recorder saw the same lifecycle transitions
        let evs = flight.dump(usize::MAX);
        let kind_for = |id: u64, kind: FlightKind| {
            evs.iter().any(|e| e.req == id && e.kind == kind)
        };
        assert!(kind_for(0, FlightKind::Enqueue));
        assert!(kind_for(0, FlightKind::Admit));
        assert!(kind_for(0, FlightKind::Preempt));
        assert!(kind_for(0, FlightKind::Resume));
        assert!(kind_for(0, FlightKind::Finish));
        assert!(kind_for(3, FlightKind::Shed));
        assert!(kind_for(3, FlightKind::Finish));
        // every recorded event fits the ring (no wrap in this run), and
        // the engine published a live status table along the way
        assert!(flight.recorded() <= 256);
    }
}
