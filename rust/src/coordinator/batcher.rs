//! Dynamic decode batcher: packs active sequences into the AOT-compiled
//! batch buckets {1, 2, 4, 8}, padding the last partial batch with an idle
//! slot replica (its output is discarded).

/// Batch-formation plan for one decode step.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BatchPlan {
    /// executable batch size (one of the compiled buckets)
    pub bucket: usize,
    /// indices (into the active list) of real sequences in the batch
    pub members: Vec<usize>,
    /// how many trailing slots are padding
    pub padding: usize,
}

// Bucket arithmetic moved next to the execution trait (every backend and
// the trait's default `forward_logits` need it); re-exported here so the
// coordinator-side paths keep working.
pub use crate::backend::bucket::{full_bucket_plan, smallest_covering};

/// Greedy bucket packing: take as many sequences as fit the largest bucket;
/// the remainder uses the smallest bucket that covers it.
#[derive(Debug, Clone)]
pub struct DecodeBatcher {
    /// ascending compiled batch sizes
    pub buckets: Vec<usize>,
}

impl DecodeBatcher {
    pub fn new(mut buckets: Vec<usize>) -> Self {
        assert!(!buckets.is_empty());
        buckets.sort_unstable();
        Self { buckets }
    }

    /// Plan the decode batches for `n_active` sequences (indices 0..n).
    pub fn plan(&self, n_active: usize) -> Vec<BatchPlan> {
        let mut plans = Vec::new();
        let largest = *self.buckets.last().unwrap();
        let mut next = 0usize;
        let mut remaining = n_active;
        while remaining > 0 {
            let take = remaining.min(largest);
            let bucket = smallest_covering(&self.buckets, take).unwrap_or(largest);
            let members: Vec<usize> = (next..next + take).collect();
            plans.push(BatchPlan { bucket, members, padding: bucket - take });
            next += take;
            remaining -= take;
        }
        plans
    }

    /// Total padded-slot fraction for a given active count (efficiency
    /// metric the batching policy minimizes).
    pub fn waste(&self, n_active: usize) -> f64 {
        let plans = self.plan(n_active);
        let padded: usize = plans.iter().map(|p| p.padding).sum();
        let total: usize = plans.iter().map(|p| p.bucket).sum();
        if total == 0 { 0.0 } else { padded as f64 / total as f64 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn batcher() -> DecodeBatcher {
        DecodeBatcher::new(vec![1, 2, 4, 8])
    }

    #[test]
    fn exact_bucket_no_padding() {
        for n in [1usize, 2, 4, 8] {
            let p = batcher().plan(n);
            assert_eq!(p.len(), 1);
            assert_eq!(p[0].bucket, n);
            assert_eq!(p[0].padding, 0);
        }
    }

    #[test]
    fn intermediate_counts_use_next_bucket() {
        let p = batcher().plan(3);
        assert_eq!(p.len(), 1);
        assert_eq!(p[0].bucket, 4);
        assert_eq!(p[0].padding, 1);
        assert_eq!(p[0].members, vec![0, 1, 2]);
    }

    #[test]
    fn overflow_splits_into_multiple_batches() {
        let p = batcher().plan(13);
        assert_eq!(p.len(), 2);
        assert_eq!(p[0].bucket, 8);
        assert_eq!(p[0].padding, 0);
        assert_eq!(p[1].bucket, 8); // 5 -> bucket 8
        assert_eq!(p[1].padding, 3);
        let all: Vec<usize> = p.iter().flat_map(|b| b.members.clone()).collect();
        assert_eq!(all, (0..13).collect::<Vec<_>>());
    }

    #[test]
    fn zero_active_is_empty() {
        assert!(batcher().plan(0).is_empty());
    }

    #[test]
    fn waste_decreases_at_bucket_sizes() {
        let b = batcher();
        assert_eq!(b.waste(8), 0.0);
        assert!(b.waste(5) > 0.0);
        assert!(b.waste(5) < 0.5);
    }

    #[test]
    fn single_bucket_batcher() {
        let b = DecodeBatcher::new(vec![4]);
        let p = b.plan(6);
        assert_eq!(p.len(), 2);
        assert_eq!(p[0].padding, 0);
        assert_eq!(p[1].padding, 2);
    }

    #[test]
    fn full_bucket_plan_covers_largest_first() {
        let buckets = [32usize, 64, 128, 256];
        assert_eq!(full_bucket_plan(&buckets, 0), (vec![], 0));
        assert_eq!(full_bucket_plan(&buckets, 31), (vec![], 31));
        assert_eq!(full_bucket_plan(&buckets, 32), (vec![32], 0));
        assert_eq!(full_bucket_plan(&buckets, 300), (vec![256, 32], 12));
        let (chunks, rest) = full_bucket_plan(&buckets, 511);
        assert_eq!(chunks.iter().sum::<usize>() + rest, 511);
        assert!(rest < 32);
    }

    #[test]
    fn smallest_covering_picks_minimal_bucket() {
        let buckets = [32usize, 64, 128, 256];
        assert_eq!(smallest_covering(&buckets, 1), Some(32));
        assert_eq!(smallest_covering(&buckets, 32), Some(32));
        assert_eq!(smallest_covering(&buckets, 33), Some(64));
        assert_eq!(smallest_covering(&buckets, 256), Some(256));
        assert_eq!(smallest_covering(&buckets, 257), None);
        assert_eq!(smallest_covering(&[], 1), None);
    }
}
