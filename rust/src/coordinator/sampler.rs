//! Token sampling: the [`SamplingParams`] surface carried on every
//! [`Request`], the [`Sampler`] that turns a logits row into a token, and
//! the string stop-sequence [`StopMatcher`] with its stream-side
//! [`OutStream`] wrapper.
//!
//! Design constraints, in order:
//!
//! 1. **`temperature = 0` is bit-exact with the pre-sampler greedy path.**
//!    The default [`SamplingParams`] routes straight through
//!    [`request::argmax`] on the *raw* logits row — no copy, no float
//!    transform — so every existing token-exactness suite (engine vs
//!    batch, spec vs plain, pool vs single worker, cache on vs off) holds
//!    unchanged.
//! 2. **Reproducible and position-keyed.** All randomness derives from
//!    [`keyed_uniform`]`(seed, position, salt)` — a stateless hash of the
//!    request seed, the token position, and a per-use salt — instead of a
//!    sequential RNG.  This is what makes sampled speculative decoding
//!    (speculative.rs) line up with the plain engine: the draw used at
//!    generation position `i` does not depend on *how many* draws happened
//!    before it (draft rounds burn extra randomness for rejected
//!    positions), only on `i` itself.
//! 3. **Documented processing order.** Logits are transformed as:
//!    repetition penalty → presence/frequency penalties → logit bias →
//!    temperature → top-k → softmax → top-p → renormalize.  Penalty state
//!    (`seen` for repetition, per-token counts for presence/frequency) is
//!    only tracked when a penalty is active, so penalty-free requests pay
//!    nothing.
//!
//! [`Request`]: super::request::Request
//! [`request::argmax`]: super::request::argmax

use std::collections::{HashMap, HashSet, VecDeque};

use super::request::{argmax, Event, Request};
use crate::util::rng::Rng;

/// Salt for the primary per-position token draw (plain sampling, draft
/// proposals, and the full-acceptance bonus token).
pub const SALT_SAMPLE: u64 = 0x5341_4D50;
/// Salt for the speculative accept/reject coin at each draft position.
pub const SALT_ACCEPT: u64 = 0x4143_4350;
/// Salt for the residual-distribution resample after a draft rejection.
pub const SALT_RESAMPLE: u64 = 0x5245_534D;

/// Stateless uniform draw in `[0, 1)` keyed by (request seed, generation
/// position, salt).  Same key → same draw, always — the speculative
/// engine's lossless-acceptance coupling depends on it (see module doc).
pub fn keyed_uniform(seed: u64, index: usize, salt: u64) -> f64 {
    let s = seed
        .wrapping_add((index as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15))
        .wrapping_add(salt.wrapping_mul(0xBF58_476D_1CE4_E5B9));
    Rng::new(s).uniform()
}

/// Per-request sampling configuration, carried on
/// [`Request::sampling`](super::request::Request::sampling).
///
/// The default is **pure greedy** (`temperature = 0`, every filter off),
/// which the engines fast-path to a raw [`argmax`] — bit-exact with the
/// pre-sampler behavior.
#[derive(Debug, Clone, PartialEq)]
pub struct SamplingParams {
    /// softmax temperature; `<= 0` selects greedy argmax decoding
    pub temperature: f32,
    /// keep only the `top_k` highest logits before softmax (`0` = off)
    pub top_k: usize,
    /// nucleus sampling: keep the smallest probability-sorted prefix with
    /// cumulative mass `>= top_p` (`>= 1.0` = off; at least one token is
    /// always kept)
    pub top_p: f32,
    /// divide positive / multiply negative logits of every token already
    /// seen (prompt + generated) by this factor (`1.0` = off)
    pub repetition_penalty: f32,
    /// flat logit subtraction for every token generated at least once
    pub presence_penalty: f32,
    /// per-occurrence logit subtraction (count × penalty) over generated
    /// tokens
    pub frequency_penalty: f32,
    /// additive per-token logit adjustments, applied after penalties
    pub logit_bias: Vec<(u32, f32)>,
    /// string stop sequences matched against the rendered token stream
    /// (decimal token ids joined by single spaces); on match the request
    /// retires with [`FinishReason::StopSequence`] and the matched text is
    /// withheld from the stream
    ///
    /// [`FinishReason::StopSequence`]: super::request::FinishReason::StopSequence
    pub stop_sequences: Vec<String>,
    /// seed for the per-request position-keyed RNG ([`keyed_uniform`])
    pub seed: u64,
}

impl Default for SamplingParams {
    fn default() -> Self {
        Self {
            temperature: 0.0,
            top_k: 0,
            top_p: 1.0,
            repetition_penalty: 1.0,
            presence_penalty: 0.0,
            frequency_penalty: 0.0,
            logit_bias: Vec::new(),
            stop_sequences: Vec::new(),
            seed: 0,
        }
    }
}

impl SamplingParams {
    /// Greedy decoding? (`temperature <= 0`)
    pub fn is_greedy(&self) -> bool {
        self.temperature <= 0.0
    }

    /// Does any logits transform apply before the argmax/softmax?
    pub fn has_processing(&self) -> bool {
        self.repetition_penalty != 1.0
            || self.presence_penalty != 0.0
            || self.frequency_penalty != 0.0
            || !self.logit_bias.is_empty()
    }

    /// Pure greedy: raw argmax over the untouched logits row — the
    /// bit-exactness fast path the engines take for default requests.
    pub fn is_pure_greedy(&self) -> bool {
        self.is_greedy() && !self.has_processing()
    }
}

/// Per-request sampling state: the params plus the penalty bookkeeping
/// (tokens seen for repetition, generation counts for presence/frequency).
///
/// The sampler is `Clone` so the speculative engine can run a draft round
/// on a scratch copy and only commit `observe()` calls for tokens the
/// verifier accepted.
#[derive(Debug, Clone)]
pub struct Sampler {
    params: SamplingParams,
    /// tokens in the prompt or generated so far (repetition penalty)
    seen: HashSet<u32>,
    /// generated-token occurrence counts (presence/frequency penalties)
    counts: HashMap<u32, u32>,
}

impl Sampler {
    pub fn new(params: SamplingParams) -> Self {
        Self { params, seen: HashSet::new(), counts: HashMap::new() }
    }

    pub fn params(&self) -> &SamplingParams {
        &self.params
    }

    fn tracks_penalties(&self) -> bool {
        self.params.repetition_penalty != 1.0
            || self.params.presence_penalty != 0.0
            || self.params.frequency_penalty != 0.0
    }

    /// Record the prompt tokens (repetition penalty covers prompt +
    /// generated; presence/frequency cover generated only).
    pub fn observe_context(&mut self, prompt: &[u32]) {
        if !self.tracks_penalties() {
            return;
        }
        self.seen.extend(prompt.iter().copied());
    }

    /// Record one committed generated token.
    pub fn observe(&mut self, tok: u32) {
        if !self.tracks_penalties() {
            return;
        }
        self.seen.insert(tok);
        *self.counts.entry(tok).or_insert(0) += 1;
    }

    /// Apply penalties + bias (the pre-temperature transforms), in the
    /// documented order: repetition → presence/frequency → bias.
    fn processed(&self, logits: &[f32]) -> Vec<f32> {
        let mut l = logits.to_vec();
        let rp = self.params.repetition_penalty;
        if rp != 1.0 && rp > 0.0 {
            for &t in &self.seen {
                if let Some(v) = l.get_mut(t as usize) {
                    // the CTRL-paper rule: shrink positive logits, push
                    // negative ones further down
                    *v = if *v > 0.0 { *v / rp } else { *v * rp };
                }
            }
        }
        if self.params.presence_penalty != 0.0 || self.params.frequency_penalty != 0.0 {
            for (&t, &c) in &self.counts {
                if let Some(v) = l.get_mut(t as usize) {
                    *v -= self.params.presence_penalty
                        + self.params.frequency_penalty * c as f32;
                }
            }
        }
        for &(t, b) in &self.params.logit_bias {
            if let Some(v) = l.get_mut(t as usize) {
                *v += b;
            }
        }
        l
    }

    /// Sample one token for generation position `index`.
    ///
    /// Greedy params reduce to [`argmax`] (over raw logits when no
    /// penalty/bias applies — the bit-exact fast path); otherwise an
    /// inverse-CDF draw from [`Sampler::dist`] using the position-keyed
    /// uniform.
    pub fn sample(&self, logits: &[f32], index: usize) -> u32 {
        if self.params.is_greedy() {
            if !self.params.has_processing() {
                return argmax(logits);
            }
            return argmax(&self.processed(logits));
        }
        let dist = self.dist(logits);
        Self::pick(&dist, keyed_uniform(self.params.seed, index, SALT_SAMPLE))
    }

    /// The full post-filter probability distribution over the vocabulary
    /// (zeros for filtered-out tokens).  Only meaningful for
    /// `temperature > 0`; the speculative engine uses these rows directly
    /// for the rejection-sampling acceptance rule.
    ///
    /// Pipeline: penalties/bias → NaN→-inf → ÷temperature → sort (value
    /// desc, index asc) → top-k cut → softmax (max-subtracted, f64
    /// accumulation) → top-p cut (≥ 1 token kept) → renormalize.
    pub fn dist(&self, logits: &[f32]) -> Vec<f32> {
        let mut l = if self.params.has_processing() {
            self.processed(logits)
        } else {
            logits.to_vec()
        };
        let temp = self.params.temperature;
        debug_assert!(temp > 0.0, "dist() requires temperature > 0");
        for v in l.iter_mut() {
            *v = if v.is_nan() { f32::NEG_INFINITY } else { *v / temp };
        }
        let mut idx: Vec<usize> = (0..l.len()).collect();
        // value descending, index ascending on ties — deterministic and
        // total (NaNs were cleared above)
        idx.sort_by(|&a, &b| {
            l[b].partial_cmp(&l[a]).unwrap_or(std::cmp::Ordering::Equal).then(a.cmp(&b))
        });
        let k = if self.params.top_k == 0 { l.len() } else { self.params.top_k.min(l.len()) };
        idx.truncate(k.max(1));
        let mx = l[idx[0]];
        let mut out = vec![0.0f32; l.len()];
        if mx == f32::NEG_INFINITY || !mx.is_finite() {
            // every candidate masked: degenerate point mass on the
            // first-index survivor
            out[idx[0]] = 1.0;
            return out;
        }
        let mut probs: Vec<f64> = idx.iter().map(|&i| ((l[i] - mx) as f64).exp()).collect();
        let sum: f64 = probs.iter().sum();
        for p in probs.iter_mut() {
            *p /= sum;
        }
        let keep = if self.params.top_p < 1.0 {
            let target = (self.params.top_p as f64).max(0.0);
            let mut cum = 0.0f64;
            let mut n = 0usize;
            for &q in &probs {
                cum += q;
                n += 1;
                if cum >= target {
                    break;
                }
            }
            n.max(1)
        } else {
            probs.len()
        };
        let kept: f64 = probs[..keep].iter().sum();
        for j in 0..keep {
            out[idx[j]] = (probs[j] / kept) as f32;
        }
        out
    }

    /// Inverse-CDF draw from a (possibly unnormalized) weight vector.
    /// Non-positive / non-finite weights are skipped; an all-zero vector
    /// falls back to token 0.
    pub fn pick(dist: &[f32], u: f64) -> u32 {
        let total: f64 =
            dist.iter().filter(|p| p.is_finite() && **p > 0.0).map(|&p| p as f64).sum();
        if total <= 0.0 {
            return 0;
        }
        let target = u * total;
        let mut cum = 0.0f64;
        let mut last = 0u32;
        for (i, &p) in dist.iter().enumerate() {
            if !p.is_finite() || p <= 0.0 {
                continue;
            }
            cum += p as f64;
            last = i as u32;
            if cum > target {
                return i as u32;
            }
        }
        // float round-off pushed the target past the final cum: the last
        // positive-weight token
        last
    }
}

/// The result of pushing one token into a [`StopMatcher`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum StopScan {
    /// no stop sequence completed; these tokens are now safe to stream
    /// (tokens overlapping a *partial* match stay held back)
    Continue(Vec<u32>),
    /// a stop sequence completed; `release` is the final safe-to-stream
    /// tail (tokens strictly before the match), everything else —
    /// including the matched text — is withheld
    Stopped { release: Vec<u32> },
}

/// Incremental string stop-sequence detector over the rendered token
/// stream.
///
/// Tokens render as their decimal ids joined by single spaces (the crate
/// has no text tokenizer), so `"7 19"` stops generation the moment token
/// 19 follows token 7.  Matching is resilient to sequences spanning token
/// boundaries: after each push the matcher computes the longest tail of
/// the rendered text that is a proper prefix of any stop sequence and
/// holds back every token overlapping it, releasing the rest — so a
/// partial match is never streamed and then "un-streamed".
#[derive(Debug, Clone)]
pub struct StopMatcher {
    seqs: Vec<String>,
    /// rendered text kept for matching (suffix of the full stream)
    tail: String,
    /// absolute byte offset of `tail[0]` in the full rendered stream
    base: usize,
    /// total rendered bytes so far
    total: usize,
    /// held-back tokens: (token, absolute byte start, rendered length)
    pending: VecDeque<(u32, usize, usize)>,
}

impl StopMatcher {
    pub fn new(seqs: &[String]) -> Self {
        Self {
            seqs: seqs.iter().filter(|s| !s.is_empty()).cloned().collect(),
            tail: String::new(),
            base: 0,
            total: 0,
            pending: VecDeque::new(),
        }
    }

    /// The canonical rendering of one token at stream position `first`.
    pub fn render(tok: u32, first: bool) -> String {
        if first {
            tok.to_string()
        } else {
            format!(" {tok}")
        }
    }

    /// Longest `l >= 1` such that the last `l` bytes of `tail` equal a
    /// *proper* prefix of some stop sequence (a full match was already
    /// ruled out by the caller).
    fn hold_len(&self) -> usize {
        let tb = self.tail.as_bytes();
        let mut hold = 0usize;
        for s in &self.seqs {
            let sb = s.as_bytes();
            let max_l = sb.len().saturating_sub(1).min(tb.len());
            for l in (hold + 1..=max_l).rev() {
                if tb[tb.len() - l..] == sb[..l] {
                    hold = hold.max(l);
                    break;
                }
            }
        }
        hold
    }

    /// Earliest full-match byte offset (absolute) across all sequences.
    fn earliest_match(&self) -> Option<usize> {
        self.seqs
            .iter()
            .filter_map(|s| self.tail.find(s.as_str()).map(|p| self.base + p))
            .min()
    }

    /// Push one token; returns which pending tokens are now releasable,
    /// or the stop verdict.
    pub fn push(&mut self, tok: u32) -> StopScan {
        let text = Self::render(tok, self.total == 0);
        let start = self.total;
        self.tail.push_str(&text);
        self.total += text.len();
        self.pending.push_back((tok, start, text.len()));

        if let Some(match_abs) = self.earliest_match() {
            // release tokens entirely before the match; the matched text
            // (and any token overlapping it) is withheld
            let mut release = Vec::new();
            while let Some(&(t, s, len)) = self.pending.front() {
                if s + len <= match_abs {
                    release.push(t);
                    self.pending.pop_front();
                } else {
                    break;
                }
            }
            return StopScan::Stopped { release };
        }

        let hold = self.hold_len();
        let hold_from = self.total - hold;
        let mut release = Vec::new();
        while let Some(&(t, s, len)) = self.pending.front() {
            if s + len <= hold_from {
                release.push(t);
                self.pending.pop_front();
            } else {
                break;
            }
        }
        // trim the tail: matching never needs text before the first
        // held-back token (or before the hold window when nothing is held)
        let keep_from = self.pending.front().map(|&(_, s, _)| s).unwrap_or(self.total);
        if keep_from > self.base {
            self.tail.drain(..keep_from - self.base);
            self.base = keep_from;
        }
        StopScan::Continue(release)
    }

    /// End of generation without a match: everything held back is safe.
    pub fn flush(&mut self) -> Vec<u32> {
        self.pending.drain(..).map(|(t, _, _)| t).collect()
    }
}

/// Stream-side wrapper the engines use: routes committed tokens through
/// the optional [`StopMatcher`], emits [`Event::Token`] only for released
/// tokens, and tracks how many tokens are client-visible (the
/// [`FinishedRequest::generated`] truncation point when a stop sequence
/// fires).
///
/// [`FinishedRequest::generated`]: super::request::FinishedRequest::generated
#[derive(Debug, Clone)]
pub(crate) struct OutStream {
    matcher: Option<StopMatcher>,
    streamed: usize,
}

impl OutStream {
    pub(crate) fn new(params: &SamplingParams) -> Self {
        let matcher = if params.stop_sequences.iter().any(|s| !s.is_empty()) {
            Some(StopMatcher::new(&params.stop_sequences))
        } else {
            None
        };
        Self { matcher, streamed: 0 }
    }

    /// Route one committed token; returns `true` when a stop sequence
    /// completed (the engine should retire the request with
    /// `FinishReason::StopSequence`).
    pub(crate) fn push(&mut self, req: &Request, tok: u32) -> bool {
        match &mut self.matcher {
            None => {
                req.emit(Event::Token { tok, index: self.streamed });
                self.streamed += 1;
                false
            }
            Some(m) => match m.push(tok) {
                StopScan::Continue(release) => {
                    for t in release {
                        req.emit(Event::Token { tok: t, index: self.streamed });
                        self.streamed += 1;
                    }
                    false
                }
                StopScan::Stopped { release } => {
                    for t in release {
                        req.emit(Event::Token { tok: t, index: self.streamed });
                        self.streamed += 1;
                    }
                    true
                }
            },
        }
    }

    /// Generation ended without a stop-sequence match: release any
    /// held-back partial-match tokens.
    pub(crate) fn flush(&mut self, req: &Request) {
        if let Some(m) = &mut self.matcher {
            for t in m.flush() {
                req.emit(Event::Token { tok: t, index: self.streamed });
                self.streamed += 1;
            }
        }
    }

    /// Number of client-visible tokens (== `generated.len()` unless a stop
    /// sequence withheld a tail).
    pub(crate) fn visible(&self) -> usize {
        self.streamed
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sampled(temp: f32) -> SamplingParams {
        SamplingParams { temperature: temp, seed: 42, ..SamplingParams::default() }
    }

    #[test]
    fn sampler_default_is_pure_greedy_argmax() {
        let p = SamplingParams::default();
        assert!(p.is_pure_greedy());
        let s = Sampler::new(p);
        let logits = [0.1f32, 3.0, -1.0, 2.9];
        for index in 0..4 {
            assert_eq!(s.sample(&logits, index), argmax(&logits));
        }
    }

    #[test]
    fn sampler_keyed_uniform_is_stateless_and_salted() {
        let a = keyed_uniform(7, 3, SALT_SAMPLE);
        assert_eq!(a, keyed_uniform(7, 3, SALT_SAMPLE));
        assert_ne!(a, keyed_uniform(7, 4, SALT_SAMPLE));
        assert_ne!(a, keyed_uniform(8, 3, SALT_SAMPLE));
        assert_ne!(a, keyed_uniform(7, 3, SALT_ACCEPT));
        assert_ne!(a, keyed_uniform(7, 3, SALT_RESAMPLE));
        assert!((0.0..1.0).contains(&a));
    }

    #[test]
    fn sampler_top_k_edges() {
        let logits = [1.0f32, 2.0, 3.0, 4.0];
        // k >= vocab: identical to k = 0 (off)
        let off = Sampler::new(SamplingParams { top_k: 0, ..sampled(1.0) });
        let big = Sampler::new(SamplingParams { top_k: 99, ..sampled(1.0) });
        assert_eq!(off.dist(&logits), big.dist(&logits));
        // k = 1: point mass on the argmax
        let one = Sampler::new(SamplingParams { top_k: 1, ..sampled(1.0) });
        let d = one.dist(&logits);
        assert_eq!(d, vec![0.0, 0.0, 0.0, 1.0]);
        for index in 0..8 {
            assert_eq!(one.sample(&logits, index), 3);
        }
    }

    #[test]
    fn sampler_top_p_edges() {
        let logits = [1.0f32, 2.0, 3.0, 4.0];
        // p = 1.0: off — full softmax support, sums to 1
        let off = Sampler::new(SamplingParams { top_p: 1.0, ..sampled(1.0) });
        let d = off.dist(&logits);
        assert!(d.iter().all(|&p| p > 0.0));
        assert!((d.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        // p -> 0: at least one token survives (the argmax), renormalized
        let tiny = Sampler::new(SamplingParams { top_p: 1e-9, ..sampled(1.0) });
        let d = tiny.dist(&logits);
        assert_eq!(d, vec![0.0, 0.0, 0.0, 1.0]);
        // mid p keeps a proper prefix of the sorted tokens and renormalizes
        let mid = Sampler::new(SamplingParams { top_p: 0.6, ..sampled(1.0) });
        let d = mid.dist(&logits);
        assert!(d[3] > 0.0 && d[0] == 0.0);
        assert!((d.iter().sum::<f32>() - 1.0).abs() < 1e-6);
    }

    #[test]
    fn sampler_temperature_sharpens() {
        let logits = [1.0f32, 2.0, 3.0, 4.0];
        let hot = Sampler::new(sampled(2.0)).dist(&logits);
        let cold = Sampler::new(sampled(0.25)).dist(&logits);
        assert!(cold[3] > hot[3], "lower temperature concentrates mass on the max");
    }

    #[test]
    fn sampler_penalty_application_order() {
        // repetition divides the positive logit FIRST, then
        // presence+frequency subtract, then bias adds — order changes the
        // result, so pin it.
        let params = SamplingParams {
            temperature: 1.0,
            repetition_penalty: 2.0,
            presence_penalty: 0.5,
            frequency_penalty: 0.25,
            logit_bias: vec![(1, 3.0)],
            seed: 1,
            ..SamplingParams::default()
        };
        let mut s = Sampler::new(params);
        s.observe_context(&[1]); // token 1 in the prompt
        s.observe(1); // generated twice
        s.observe(1);
        let l = s.processed(&[0.0f32, 4.0, -4.0]);
        // token 1: 4.0 / 2.0 (repetition) - (0.5 + 0.25 * 2) (pres+freq)
        //          + 3.0 (bias) = 4.0
        assert!((l[1] - 4.0).abs() < 1e-6, "got {}", l[1]);
        // untouched token
        assert_eq!(l[0], 0.0);
        // negative logits are multiplied by the repetition penalty
        let mut s2 = Sampler::new(SamplingParams {
            temperature: 1.0,
            repetition_penalty: 2.0,
            seed: 1,
            ..SamplingParams::default()
        });
        s2.observe(2);
        let l2 = s2.processed(&[0.0f32, 4.0, -4.0]);
        assert_eq!(l2[2], -8.0);
    }

    #[test]
    fn sampler_logit_bias_overrides_stop_token_choice() {
        // a strong negative bias on the would-be argmax flips the greedy
        // pick — the "ban a stop token" use case
        let params = SamplingParams {
            logit_bias: vec![(1, -100.0)],
            ..SamplingParams::default()
        };
        let s = Sampler::new(params);
        let logits = [0.1f32, 3.0, -1.0, 2.9];
        assert_eq!(argmax(&logits), 1);
        assert_eq!(s.sample(&logits, 0), 3);
    }

    #[test]
    fn sampler_nan_logits_never_win() {
        let s = Sampler::new(sampled(1.0));
        let logits = [f32::NAN, 1.0, f32::NAN, 5.0];
        let d = s.dist(&logits);
        assert_eq!(d[0], 0.0);
        assert_eq!(d[2], 0.0);
        assert!(d[3] > d[1]);
        for index in 0..16 {
            let t = s.sample(&logits, index);
            assert!(t == 1 || t == 3);
        }
    }

    #[test]
    fn sampler_pick_inverse_cdf() {
        let d = [0.25f32, 0.0, 0.5, 0.25];
        assert_eq!(Sampler::pick(&d, 0.0), 0);
        assert_eq!(Sampler::pick(&d, 0.24), 0);
        assert_eq!(Sampler::pick(&d, 0.26), 2);
        assert_eq!(Sampler::pick(&d, 0.74), 2);
        assert_eq!(Sampler::pick(&d, 0.76), 3);
        assert_eq!(Sampler::pick(&d, 0.999_999), 3);
        // unnormalized weights and the all-zero fallback
        assert_eq!(Sampler::pick(&[0.0, 2.0, 2.0], 0.49), 1);
        assert_eq!(Sampler::pick(&[0.0, 2.0, 2.0], 0.51), 2);
        assert_eq!(Sampler::pick(&[0.0, 0.0], 0.5), 0);
    }

    #[test]
    fn sampler_same_seed_same_stream_different_seed_diverges() {
        let logits: Vec<f32> = (0..32).map(|i| ((i * 37 % 13) as f32) * 0.3).collect();
        let a = Sampler::new(SamplingParams { seed: 5, ..sampled(1.0) });
        let b = Sampler::new(SamplingParams { seed: 5, ..sampled(1.0) });
        let c = Sampler::new(SamplingParams { seed: 6, ..sampled(1.0) });
        let ta: Vec<u32> = (0..64).map(|i| a.sample(&logits, i)).collect();
        let tb: Vec<u32> = (0..64).map(|i| b.sample(&logits, i)).collect();
        let tc: Vec<u32> = (0..64).map(|i| c.sample(&logits, i)).collect();
        assert_eq!(ta, tb);
        assert_ne!(ta, tc);
    }

    #[test]
    fn stop_matcher_single_token_sequence() {
        let mut m = StopMatcher::new(&["19".to_string()]);
        assert_eq!(m.push(7), StopScan::Continue(vec![7]));
        assert_eq!(m.push(19), StopScan::Stopped { release: vec![] });
    }

    #[test]
    fn stop_matcher_spans_token_boundary_and_holds_partial() {
        // stop sequence "7 19" spans two rendered tokens; pushing 7 must
        // hold it back (partial match), a following 19 completes the stop,
        // a following non-19 releases the held 7
        let mut m = StopMatcher::new(&["7 19".to_string()]);
        assert_eq!(m.push(3), StopScan::Continue(vec![3]));
        // "3 7": the trailing "7" (and its leading space: " 7" contains
        // the prefix "7 "? no — "7" alone is the proper prefix) is held
        assert_eq!(m.push(7), StopScan::Continue(vec![]));
        let mut done = m.clone();
        assert_eq!(done.push(19), StopScan::Stopped { release: vec![] });
        // divergence: "3 7 191" does NOT contain "7 19"? it does — "7 19"
        // matches inside "7 191".  Use 21 instead.
        assert_eq!(m.push(21), StopScan::Continue(vec![7, 21]));
    }

    #[test]
    fn stop_matcher_match_inside_longer_render() {
        // "7 19" occurs inside "... 7 191 ..." because rendered text is
        // matched as a plain substring — pin that behavior
        let mut m = StopMatcher::new(&["7 19".to_string()]);
        assert_eq!(m.push(7), StopScan::Continue(vec![]));
        assert_eq!(m.push(191), StopScan::Stopped { release: vec![] });
    }

    #[test]
    fn stop_matcher_flush_releases_held_tokens() {
        let mut m = StopMatcher::new(&["7 19".to_string()]);
        assert_eq!(m.push(5), StopScan::Continue(vec![5]));
        assert_eq!(m.push(7), StopScan::Continue(vec![]));
        assert_eq!(m.flush(), vec![7]);
        assert_eq!(m.flush(), Vec::<u32>::new());
    }

    #[test]
    fn stop_matcher_releases_prefix_before_match() {
        let mut m = StopMatcher::new(&["8 9".to_string()]);
        assert_eq!(m.push(1), StopScan::Continue(vec![1]));
        assert_eq!(m.push(8), StopScan::Continue(vec![]));
        // match completes; token 1 already released, 8 and 9 withheld
        assert_eq!(m.push(9), StopScan::Stopped { release: vec![] });
    }

    #[test]
    fn out_stream_emits_only_released_tokens_and_truncates() {
        let params = SamplingParams {
            stop_sequences: vec!["7 19".to_string()],
            ..SamplingParams::default()
        };
        let mut req = Request::new(1, vec![0], 8, "fp32");
        let h = req.attach_events();
        let mut out = OutStream::new(&params);
        assert!(!out.push(&req, 3));
        assert!(!out.push(&req, 7)); // held: partial match
        assert!(out.push(&req, 19)); // stop completes
        assert_eq!(out.visible(), 1);
        let mut toks = Vec::new();
        while let Some(Event::Token { tok, index }) = h.try_event() {
            assert_eq!(index, toks.len());
            toks.push(tok);
        }
        assert_eq!(toks, vec![3]);
    }

    #[test]
    fn out_stream_flush_streams_held_tail() {
        let params = SamplingParams {
            stop_sequences: vec!["7 19".to_string()],
            ..SamplingParams::default()
        };
        let mut req = Request::new(1, vec![0], 8, "fp32");
        let h = req.attach_events();
        let mut out = OutStream::new(&params);
        assert!(!out.push(&req, 7));
        assert_eq!(out.visible(), 0);
        out.flush(&req);
        assert_eq!(out.visible(), 1);
        assert!(matches!(h.try_event(), Some(Event::Token { tok: 7, index: 0 })));
    }

    #[test]
    fn out_stream_without_stop_sequences_passes_through() {
        let mut req = Request::new(1, vec![0], 8, "fp32");
        let h = req.attach_events();
        let mut out = OutStream::new(&SamplingParams::default());
        for (i, t) in [4u32, 5, 6].into_iter().enumerate() {
            assert!(!out.push(&req, t));
            assert!(matches!(h.try_event(), Some(Event::Token { tok, index }) if tok == t && index == i));
        }
        assert_eq!(out.visible(), 3);
    }
}
