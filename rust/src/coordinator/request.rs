//! Request and result types flowing through the coordinator.

use std::time::Instant;

/// An inference request (prompt + generation budget).
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<u32>,
    pub max_new_tokens: usize,
    /// model variant to execute ("fp32" or "fastmamba")
    pub variant: String,
    /// optional stop token (generation halts when sampled)
    pub stop_token: Option<u32>,
    /// optional conversation id for the state cache: on completion the
    /// request's end-of-turn SSM state is stored under this id, and a
    /// follow-up request carrying the same id whose prompt extends the
    /// stored transcript resumes from that state with zero prefix
    /// recompute (`statecache::StateCache::lookup_session`)
    pub session_id: Option<u64>,
    /// when the request entered the system (set at construction) — the
    /// anchor for TTFT/latency, so queue time in a pool dispatcher or an
    /// engine's pending list counts toward the reported latency
    pub submitted_at: Instant,
}

impl Request {
    pub fn new(id: u64, prompt: Vec<u32>, max_new_tokens: usize, variant: &str) -> Self {
        Self {
            id,
            prompt,
            max_new_tokens,
            variant: variant.to_string(),
            stop_token: None,
            session_id: None,
            submitted_at: Instant::now(),
        }
    }

    /// Tag the request as one turn of a multi-turn session.
    pub fn with_session(mut self, session_id: u64) -> Self {
        self.session_id = Some(session_id);
        self
    }
}

/// Speculative-decoding accounting for one request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SpecStats {
    /// draft tokens proposed by the drafter
    pub drafted: u64,
    /// draft tokens accepted by the verifier
    pub accepted: u64,
    /// draft/verify rounds executed
    pub rounds: u64,
}

impl SpecStats {
    pub fn acceptance_rate(&self) -> f64 {
        if self.drafted == 0 {
            return 0.0;
        }
        self.accepted as f64 / self.drafted as f64
    }
}

/// Lifecycle timestamps + output of a completed request.
#[derive(Debug, Clone)]
pub struct FinishedRequest {
    pub id: u64,
    pub generated: Vec<u32>,
    /// time-to-first-token, seconds (prefill latency)
    pub ttft_s: f64,
    /// total latency from submission
    pub total_s: f64,
    pub prompt_len: usize,
    /// `Some` when the request was served by the speculative engine
    pub spec: Option<SpecStats>,
}

/// In-flight request tracking inside the engine.
#[derive(Debug)]
pub(crate) struct InFlight {
    pub req: Request,
    pub slot: usize,
    pub generated: Vec<u32>,
    /// last sampled / last prompt token to feed next
    pub next_token: u32,
    pub submitted: Instant,
    pub first_token_at: Option<Instant>,
}

/// Greedy (argmax) sampling over one logits row.
pub fn argmax(logits: &[f32]) -> u32 {
    let mut best = 0usize;
    let mut bv = f32::NEG_INFINITY;
    for (i, v) in logits.iter().enumerate() {
        if *v > bv {
            bv = *v;
            best = i;
        }
    }
    best as u32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_picks_max() {
        assert_eq!(argmax(&[0.1, 3.0, -1.0, 2.9]), 1);
        assert_eq!(argmax(&[-5.0]), 0);
    }

    #[test]
    fn request_builder() {
        let r = Request::new(7, vec![1, 2, 3], 16, "fastmamba");
        assert_eq!(r.id, 7);
        assert_eq!(r.variant, "fastmamba");
        assert!(r.stop_token.is_none());
        assert!(r.session_id.is_none());
        let r = r.with_session(99);
        assert_eq!(r.session_id, Some(99));
    }

    #[test]
    fn spec_stats_acceptance() {
        let s = SpecStats { drafted: 8, accepted: 6, rounds: 2 };
        assert!((s.acceptance_rate() - 0.75).abs() < 1e-12);
        let none = SpecStats { drafted: 0, accepted: 0, rounds: 0 };
        assert_eq!(none.acceptance_rate(), 0.0);
    }
}
