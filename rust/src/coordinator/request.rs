//! Request, result, and streaming-lifecycle types flowing through the
//! coordinator.
//!
//! The client-facing contract is a **streaming request lifecycle**: every
//! submit path ([`Engine::submit`], [`SpecEngine::submit`],
//! [`ServePool::submit`]) returns a [`SubmitHandle`] carrying a per-request
//! [`Event`] receiver plus `cancel()`.  Tokens stream out as the SSM step
//! produces them ([`Event::Token`]), a terminal [`Event::Finished`] carries
//! the full [`FinishedRequest`] with its [`FinishReason`], and abandoned or
//! over-deadline requests free their constant-size Mamba2 state slot at the
//! next engine step instead of burning it to `max_new_tokens`.
//!
//! [`Engine::submit`]: super::scheduler::Engine::submit
//! [`SpecEngine::submit`]: super::speculative::SpecEngine::submit
//! [`ServePool::submit`]: super::router::ServePool::submit

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use super::sampler::{OutStream, Sampler, SamplingParams};

/// Shared cancellation flag: one per request, shared by every clone of the
/// request (the pool dispatcher's outstanding copy, the owning worker's
/// copy) and by the [`SubmitHandle`] — so a `cancel()` reaches the owning
/// worker's engine no matter where the request currently lives.
#[derive(Debug, Clone, Default)]
pub struct CancelFlag(Arc<AtomicBool>);

impl CancelFlag {
    pub fn cancel(&self) {
        self.0.store(true, Ordering::Relaxed);
    }

    pub fn is_cancelled(&self) -> bool {
        self.0.load(Ordering::Relaxed)
    }
}

/// Why a request stopped generating (carried on [`FinishedRequest`] and
/// the terminal [`Event::Finished`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FinishReason {
    /// the generation budget (`max_new_tokens`) was reached
    Length,
    /// the configured stop token was sampled
    StopToken,
    /// a string stop sequence ([`SamplingParams::stop_sequences`])
    /// completed in the rendered token stream; `generated` is truncated
    /// to the client-visible tokens before the match
    StopSequence,
    /// the client cancelled via [`SubmitHandle::cancel`]; `generated`
    /// holds the partial output produced before the cancel was observed
    Cancelled,
    /// [`Request::deadline`] elapsed before completion; `generated` holds
    /// the partial output
    Deadline,
    /// the owning pool worker died and no survivor could re-serve the
    /// request (every worker dead); `generated` is empty
    WorkerDied,
    /// internal: a higher-priority arrival evicted this request from its
    /// state slot mid-generation.  Never surfaces on the client stream —
    /// the engine snapshots the recurrent state, requeues a continuation
    /// under the same event channel, and the stream resumes seamlessly
    /// where it left off
    Preempted,
    /// shed by admission control: the bounded pending/backlog queue
    /// (`SchedPolicy::max_queue`) was full at submission.  Retriable —
    /// nothing was generated and no state was consumed; the HTTP edge
    /// maps it to `429 Too Many Requests` + `Retry-After`
    Overloaded,
}

/// One step of a request's streaming lifecycle.
#[derive(Debug, Clone)]
pub enum Event {
    /// the first generated token exists (the TTFT marker); always
    /// immediately followed by `Token { index: 0, .. }`
    FirstToken,
    /// one generated token; `index` is its position in `generated`.  The
    /// speculative engine emits these only when the verifier consolidates
    /// a round — an emitted token is *committed*, never an unverified
    /// draft.  After a pool worker dies mid-request the replacement run
    /// re-streams from index 0 (consumers keyed by index should reset on
    /// a lower-than-expected index).
    Token { tok: u32, index: usize },
    /// terminal: the request retired (any [`FinishReason`]); also fed to
    /// the pool's aggregate `results` channel
    Finished(FinishedRequest),
}

/// Per-request handle returned by every submit path: the event stream plus
/// cancellation.
///
/// The synchronous engines ([`Engine`], [`SpecEngine`]) emit events while
/// their owner calls `step()`/`run()`, so events buffer in the channel
/// until drained (`try_event` between manual steps streams live); the
/// worker pool emits in real time from its worker threads.  Dropping the
/// handle is free — the engines' sends to a dropped receiver are no-ops —
/// so batch callers can keep ignoring the return value.
///
/// [`Engine`]: super::scheduler::Engine
/// [`SpecEngine`]: super::speculative::SpecEngine
pub struct SubmitHandle {
    id: u64,
    cancel: CancelFlag,
    events: mpsc::Receiver<Event>,
}

impl SubmitHandle {
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Request cancellation.  The owning engine observes the flag at its
    /// next step and retires the request through the normal path: slot
    /// freed, partial `generated` returned with
    /// [`FinishReason::Cancelled`], state-cache session entry still
    /// published for resumable turns.
    pub fn cancel(&self) {
        self.cancel.cancel();
    }

    pub fn is_cancelled(&self) -> bool {
        self.cancel.is_cancelled()
    }

    /// Non-blocking: the next buffered event, if any.
    pub fn try_event(&self) -> Option<Event> {
        self.events.try_recv().ok()
    }

    /// Blocking: the next event; `None` once the serving side is gone
    /// (engine dropped / pool shut down) with no event buffered.
    pub fn next_event(&self) -> Option<Event> {
        self.events.recv().ok()
    }

    /// Blocking with a timeout; `None` on timeout or disconnect.
    pub fn next_event_timeout(&self, timeout: Duration) -> Option<Event> {
        self.events.recv_timeout(timeout).ok()
    }

    /// Like [`next_event_timeout`](Self::next_event_timeout) but
    /// distinguishes a timeout (serving side still alive — poll again)
    /// from a disconnect (engine dropped / pool shut down — stop
    /// waiting).  The HTTP/SSE edge needs the distinction to probe the
    /// client connection on idle ticks without giving up on the request.
    pub fn poll_event(&self, timeout: Duration) -> Result<Event, mpsc::RecvTimeoutError> {
        self.events.recv_timeout(timeout)
    }

    /// Drain events (blocking) until the terminal [`Event::Finished`]
    /// arrives; `None` if the channel closes first.  Intermediate
    /// `FirstToken`/`Token` events are discarded — batch-style callers
    /// that only want the result.
    pub fn wait_finished(&self) -> Option<FinishedRequest> {
        while let Some(ev) = self.next_event() {
            if let Event::Finished(f) = ev {
                return Some(f);
            }
        }
        None
    }
}

/// An inference request (prompt + generation budget).
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<u32>,
    pub max_new_tokens: usize,
    /// model variant to execute ("fp32" or "fastmamba")
    pub variant: String,
    /// optional stop token (generation halts when sampled)
    pub stop_token: Option<u32>,
    /// how to turn logits into tokens (default: pure greedy argmax,
    /// bit-exact with the pre-sampler engines)
    pub sampling: SamplingParams,
    /// optional conversation id for the state cache: on completion the
    /// request's end-of-turn SSM state is stored under this id, and a
    /// follow-up request carrying the same id whose prompt extends the
    /// stored transcript resumes from that state with zero prefix
    /// recompute (`statecache::StateCache::lookup_session`)
    pub session_id: Option<u64>,
    /// optional completion deadline, measured from `submitted_at`; the
    /// owning engine checks it every step and retires an expired request
    /// with [`FinishReason::Deadline`] and whatever was generated so far
    pub deadline: Option<Duration>,
    /// admission priority: higher admits first; FIFO within a priority
    /// level (default 0 keeps the old strict-FIFO behavior)
    pub priority: i32,
    /// when the request entered the system (set at construction) — the
    /// anchor for TTFT/latency and the deadline, so queue time in a pool
    /// dispatcher or an engine's pending list counts toward both
    pub submitted_at: Instant,
    /// shared cancellation flag (all clones observe the same flag)
    pub(crate) cancel: CancelFlag,
    /// per-request event stream, attached by the submit path; `None` for
    /// requests injected through a raw pool `sender()` clone
    pub(crate) events: Option<mpsc::Sender<Event>>,
    /// saved progress of a preempted request (set by the engine when it
    /// evicts the request from its state slot; consumed at re-admission)
    pub(crate) resume: Option<Box<ResumeState>>,
}

impl Request {
    pub fn new(id: u64, prompt: Vec<u32>, max_new_tokens: usize, variant: &str) -> Self {
        Self {
            id,
            prompt,
            max_new_tokens,
            variant: variant.to_string(),
            stop_token: None,
            sampling: SamplingParams::default(),
            session_id: None,
            deadline: None,
            priority: 0,
            submitted_at: Instant::now(),
            cancel: CancelFlag::default(),
            events: None,
            resume: None,
        }
    }

    /// Tag the request as one turn of a multi-turn session.
    pub fn with_session(mut self, session_id: u64) -> Self {
        self.session_id = Some(session_id);
        self
    }

    /// Bound completion latency: past `deadline` (from submission) the
    /// request retires with [`FinishReason::Deadline`].
    pub fn with_deadline(mut self, deadline: Duration) -> Self {
        self.deadline = Some(deadline);
        self
    }

    /// Admission priority (higher first; FIFO within a level).
    pub fn with_priority(mut self, priority: i32) -> Self {
        self.priority = priority;
        self
    }

    /// Halt generation when `tok` is sampled.
    pub fn with_stop_token(mut self, tok: u32) -> Self {
        self.stop_token = Some(tok);
        self
    }

    /// Sampling configuration (temperature, top-k/top-p, penalties,
    /// logit bias, stop sequences, seed).  The default is pure greedy.
    pub fn with_sampling(mut self, sampling: SamplingParams) -> Self {
        self.sampling = sampling;
        self
    }

    /// Clone of the request's cancellation flag — for callers submitting
    /// through a raw pool `sender()` clone, which bypasses
    /// [`SubmitHandle`] creation.
    pub fn cancel_flag(&self) -> CancelFlag {
        self.cancel.clone()
    }

    /// Create the event channel and the client-side [`SubmitHandle`].
    /// Called exactly once, by the public submit paths.
    pub(crate) fn attach_events(&mut self) -> SubmitHandle {
        debug_assert!(self.events.is_none(), "request submitted twice");
        let (tx, rx) = mpsc::channel();
        self.events = Some(tx);
        SubmitHandle { id: self.id, cancel: self.cancel.clone(), events: rx }
    }

    /// Emit a lifecycle event to the handle, if one is attached and still
    /// listening (a dropped handle makes this a no-op).
    pub(crate) fn emit(&self, ev: Event) {
        if let Some(tx) = &self.events {
            let _ = tx.send(ev);
        }
    }

    /// Should this request stop now for a lifecycle reason?  Cancellation
    /// wins over an expired deadline.
    pub(crate) fn lifecycle_reason(&self) -> Option<FinishReason> {
        if self.cancel.is_cancelled() {
            return Some(FinishReason::Cancelled);
        }
        match self.deadline {
            Some(d) if self.submitted_at.elapsed() >= d => Some(FinishReason::Deadline),
            _ => None,
        }
    }
}

/// Insert into a pending queue keeping higher [`Request::priority`] first
/// and FIFO order within a priority level (all-default-priority traffic
/// degenerates to plain `push_back`, preserving the old admission order).
///
/// The queue is priority-sorted by construction, so the insertion point is
/// a `partition_point` binary search — O(log n) compares per insert, which
/// matters once `--max-queue` allows deep backlogs (the old `rposition`
/// scan walked the whole queue for every default-priority arrival).
pub(crate) fn insert_by_priority(queue: &mut VecDeque<Request>, req: Request) {
    let pos = queue.partition_point(|r| r.priority >= req.priority);
    queue.insert(pos, req);
}

/// Scheduling policy shared by both engines and the pool dispatcher
/// backlog — the `serve` flags `--age-rate`, `--preempt-threshold`, and
/// `--max-queue` map onto it 1:1.  The default is the pre-policy
/// behavior: static priorities, no preemption, unbounded queues.
#[derive(Debug, Clone)]
pub struct SchedPolicy {
    /// priority levels gained per second of queue wait (0 = aging off).
    /// With aging on, a starved low-priority request's *effective*
    /// priority rises until it overtakes a steady high-priority stream —
    /// the floor always drains.
    pub age_rate: f64,
    /// an arrival with effective priority >= this threshold may evict the
    /// lowest-priority running request from a full engine
    /// (`None` = preemption off).  Constant-size Mamba2 state makes the
    /// eviction one O(state) snapshot; the victim resumes via a
    /// state-cache session hit with zero recompute.
    pub preempt_threshold: Option<i32>,
    /// bound on the pending/backlog queue; a submission that finds the
    /// queue full is shed immediately with [`FinishReason::Overloaded`]
    /// (0 = unbounded)
    pub max_queue: usize,
}

impl Default for SchedPolicy {
    fn default() -> Self {
        Self { age_rate: 0.0, preempt_threshold: None, max_queue: 0 }
    }
}

impl SchedPolicy {
    /// Effective (aged) priority at `now`: static priority plus whole
    /// levels earned by queue wait.  Flooring to whole levels keeps
    /// FIFO-within-level exact — two same-priority requests never swap.
    pub fn effective_priority(&self, req: &Request, now: Instant) -> i64 {
        let aged = if self.age_rate > 0.0 {
            (now.saturating_duration_since(req.submitted_at).as_secs_f64()
                * self.age_rate) as i64
        } else {
            0
        };
        req.priority as i64 + aged
    }

    /// Whether a queue currently holding `len` entries must shed the next
    /// arrival.
    pub fn queue_full(&self, len: usize) -> bool {
        self.max_queue > 0 && len >= self.max_queue
    }
}

/// Re-sort a pending queue by effective (aged) priority, highest first.
/// Stable, so FIFO order within an effective-priority level is preserved;
/// with `age_rate == 0` the queue is already in this order and the call is
/// a no-op.  Returns `true` when aging actually changed the order (a
/// promotion happened) — callers count those under the aging counter.
pub(crate) fn age_queue(queue: &mut VecDeque<Request>, policy: &SchedPolicy) -> bool {
    if policy.age_rate <= 0.0 || queue.len() < 2 {
        return false;
    }
    let now = Instant::now();
    let before: Vec<u64> = queue.iter().map(|r| r.id).collect();
    queue
        .make_contiguous()
        .sort_by_key(|r| std::cmp::Reverse(policy.effective_priority(r, now)));
    queue.iter().map(|r| r.id).ne(before.iter().copied())
}

/// Saved mid-generation progress of a preempted request, carried back
/// through the pending queue so re-admission continues exactly where the
/// evicted run stopped: same sampler state (penalty bookkeeping and
/// position-keyed draws stay aligned), same stop-sequence matcher (a
/// partial match in flight keeps matching), same stream indexes (the
/// client's event stream continues without a gap or reset).
#[derive(Debug, Clone)]
pub(crate) struct ResumeState {
    /// tokens generated before preemption — the continuation's transcript
    /// is `prompt ++ generated`, and re-admission seeds `InFlight` with
    /// this vector so positions and the `max_new_tokens` budget carry over
    pub generated: Vec<u32>,
    /// per-request sampling state over the committed transcript
    pub sampler: Sampler,
    /// stop-sequence matcher + emitted-token index state
    pub stream: OutStream,
    pub first_token_at: Option<Instant>,
    pub last_token_at: Option<Instant>,
    /// internal session-cache key the preempting engine stored the slot
    /// snapshot under; re-admission probes it for an O(state) resume (a
    /// cache miss just re-prefills the transcript — slower, still exact)
    pub snapshot_sid: u64,
}

/// Speculative-decoding accounting for one request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SpecStats {
    /// draft tokens proposed by the drafter
    pub drafted: u64,
    /// draft tokens accepted by the verifier
    pub accepted: u64,
    /// draft/verify rounds executed
    pub rounds: u64,
}

impl SpecStats {
    pub fn acceptance_rate(&self) -> f64 {
        if self.drafted == 0 {
            return 0.0;
        }
        self.accepted as f64 / self.drafted as f64
    }
}

/// Lifecycle timestamps + output of a completed request.
#[derive(Debug, Clone)]
pub struct FinishedRequest {
    pub id: u64,
    pub generated: Vec<u32>,
    /// why generation stopped (partial `generated` for
    /// `Cancelled`/`Deadline`, empty for `WorkerDied`)
    pub finish_reason: FinishReason,
    /// time-to-first-token, seconds (prefill latency)
    pub ttft_s: f64,
    /// total latency from submission
    pub total_s: f64,
    pub prompt_len: usize,
    /// `Some` when the request was served by the speculative engine
    pub spec: Option<SpecStats>,
}

/// In-flight request tracking inside the engine.
#[derive(Debug)]
pub(crate) struct InFlight {
    pub req: Request,
    pub slot: usize,
    pub generated: Vec<u32>,
    /// last sampled / last prompt token to feed next
    pub next_token: u32,
    pub submitted: Instant,
    pub first_token_at: Option<Instant>,
    /// when the latest token was emitted — the TPOT (inter-token latency)
    /// anchor
    pub last_token_at: Option<Instant>,
    /// per-request sampling state (penalty bookkeeping + params)
    pub sampler: Sampler,
    /// stop-sequence-aware token emitter
    pub stream: OutStream,
}

/// Greedy (argmax) sampling over one logits row.
///
/// Semantics, pinned by unit tests:
/// - **NaN-safe**: the strict `>` comparison means `NaN` never replaces
///   the running max (`NaN > x` is false), so NaN logits can never win.
/// - **First-max tie-breaking**: on exact ties the *lowest* index wins
///   (strict `>` keeps the earlier maximum).
/// - **Degenerate rows**: an empty, all-NaN, or all-`-inf` row returns
///   token 0.
///
/// This is the `temperature = 0` fast path of
/// [`Sampler::sample`](super::sampler::Sampler::sample) — the sampler
/// calls straight into it on the raw logits row for default
/// [`SamplingParams`], which is what keeps greedy decoding bit-exact with
/// the pre-sampler engines.
pub fn argmax(logits: &[f32]) -> u32 {
    let mut best = 0usize;
    let mut bv = f32::NEG_INFINITY;
    for (i, v) in logits.iter().enumerate() {
        if *v > bv {
            bv = *v;
            best = i;
        }
    }
    best as u32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_picks_max() {
        assert_eq!(argmax(&[0.1, 3.0, -1.0, 2.9]), 1);
        assert_eq!(argmax(&[-5.0]), 0);
    }

    #[test]
    fn argmax_is_nan_safe() {
        // NaN never wins: strict > comparison rejects NaN candidates
        assert_eq!(argmax(&[f32::NAN, 1.0, 0.5]), 1);
        assert_eq!(argmax(&[1.0, f32::NAN, 0.5]), 0);
        assert_eq!(argmax(&[0.5, 1.0, f32::NAN]), 1);
        // degenerate rows fall back to token 0
        assert_eq!(argmax(&[f32::NAN, f32::NAN]), 0);
        assert_eq!(argmax(&[f32::NEG_INFINITY, f32::NEG_INFINITY]), 0);
        assert_eq!(argmax(&[]), 0);
    }

    #[test]
    fn argmax_breaks_ties_toward_first_max() {
        assert_eq!(argmax(&[2.0, 2.0, 2.0]), 0);
        assert_eq!(argmax(&[1.0, 2.0, 2.0]), 1);
    }

    #[test]
    fn argmax_single_element() {
        assert_eq!(argmax(&[f32::NEG_INFINITY]), 0);
        assert_eq!(argmax(&[42.0]), 0);
    }

    #[test]
    fn request_builder() {
        let r = Request::new(7, vec![1, 2, 3], 16, "fastmamba");
        assert_eq!(r.id, 7);
        assert_eq!(r.variant, "fastmamba");
        assert!(r.stop_token.is_none());
        assert!(r.session_id.is_none());
        assert!(r.deadline.is_none());
        assert_eq!(r.priority, 0);
        let r = r
            .with_session(99)
            .with_deadline(Duration::from_millis(250))
            .with_priority(3)
            .with_stop_token(5);
        assert_eq!(r.session_id, Some(99));
        assert_eq!(r.deadline, Some(Duration::from_millis(250)));
        assert_eq!(r.priority, 3);
        assert_eq!(r.stop_token, Some(5));
    }

    #[test]
    fn cancel_flag_is_shared_across_clones_and_handle() {
        let mut r = Request::new(1, vec![1], 4, "fp32");
        let clone = r.clone(); // e.g. the dispatcher's outstanding copy
        let h = r.attach_events();
        assert!(r.lifecycle_reason().is_none());
        h.cancel();
        assert!(h.is_cancelled());
        assert_eq!(r.lifecycle_reason(), Some(FinishReason::Cancelled));
        assert_eq!(clone.lifecycle_reason(), Some(FinishReason::Cancelled));
    }

    #[test]
    fn deadline_expires_and_cancel_wins() {
        let r = Request::new(1, vec![1], 4, "fp32").with_deadline(Duration::ZERO);
        assert_eq!(r.lifecycle_reason(), Some(FinishReason::Deadline));
        r.cancel.cancel();
        assert_eq!(r.lifecycle_reason(), Some(FinishReason::Cancelled));
        let r = Request::new(2, vec![1], 4, "fp32").with_deadline(Duration::from_secs(3600));
        assert!(r.lifecycle_reason().is_none());
    }

    #[test]
    fn events_roundtrip_and_dropped_handle_is_noop() {
        let mut r = Request::new(4, vec![1], 4, "fp32");
        r.emit(Event::FirstToken); // no channel attached: no-op
        let h = r.attach_events();
        r.emit(Event::FirstToken);
        r.emit(Event::Token { tok: 9, index: 0 });
        assert!(matches!(h.try_event(), Some(Event::FirstToken)));
        assert!(matches!(h.try_event(), Some(Event::Token { tok: 9, index: 0 })));
        assert!(h.try_event().is_none());
        drop(h);
        r.emit(Event::Token { tok: 1, index: 1 }); // dropped receiver: no-op
    }

    #[test]
    fn priority_queue_orders_high_first_fifo_within() {
        let mut q = VecDeque::new();
        let mk = |id: u64, p: i32| Request::new(id, vec![1], 1, "fp32").with_priority(p);
        insert_by_priority(&mut q, mk(0, 0));
        insert_by_priority(&mut q, mk(1, 0));
        insert_by_priority(&mut q, mk(2, 5));
        insert_by_priority(&mut q, mk(3, 5));
        insert_by_priority(&mut q, mk(4, -1));
        insert_by_priority(&mut q, mk(5, 0));
        let order: Vec<u64> = q.iter().map(|r| r.id).collect();
        assert_eq!(order, vec![2, 3, 0, 1, 5, 4]);
    }

    #[test]
    fn priority_queue_insert_matches_linear_scan_reference() {
        // partition_point must place every arrival exactly where the old
        // rposition scan did, across a mixed arrival order
        let mk = |id: u64, p: i32| Request::new(id, vec![1], 1, "fp32").with_priority(p);
        let arrivals = [0i32, 5, -3, 5, 0, 2, 2, -3, 7, 0, 5, -1];
        let mut fast = VecDeque::new();
        let mut slow: VecDeque<Request> = VecDeque::new();
        for (id, &p) in arrivals.iter().enumerate() {
            insert_by_priority(&mut fast, mk(id as u64, p));
            let r = mk(id as u64, p);
            let pos = slow
                .iter()
                .rposition(|q| q.priority >= r.priority)
                .map(|i| i + 1)
                .unwrap_or(0);
            slow.insert(pos, r);
        }
        let f: Vec<u64> = fast.iter().map(|r| r.id).collect();
        let s: Vec<u64> = slow.iter().map(|r| r.id).collect();
        assert_eq!(f, s);
    }

    #[test]
    fn aging_promotes_waited_request_past_static_priority() {
        let mut q = VecDeque::new();
        let mut low = Request::new(0, vec![1], 1, "fp32").with_priority(0);
        // the low-priority request has been queued for 10s
        low.submitted_at = Instant::now() - Duration::from_secs(10);
        insert_by_priority(&mut q, low);
        insert_by_priority(&mut q, Request::new(1, vec![1], 1, "fp32").with_priority(5));
        insert_by_priority(&mut q, Request::new(2, vec![1], 1, "fp32").with_priority(5));
        // static order: the high-priority pair first
        assert_eq!(q.front().unwrap().id, 1);

        // aging off: no reorder
        assert!(!age_queue(&mut q, &SchedPolicy::default()));
        assert_eq!(q.front().unwrap().id, 1);

        // 1 level/s: 10s of wait beats static priority 5
        let policy = SchedPolicy { age_rate: 1.0, ..SchedPolicy::default() };
        assert!(age_queue(&mut q, &policy));
        let order: Vec<u64> = q.iter().map(|r| r.id).collect();
        assert_eq!(order, vec![0, 1, 2], "fresh same-priority pair stays FIFO");
    }

    #[test]
    fn aging_preserves_fifo_within_level() {
        // same static priority, same (fresh) age: aging must never swap
        let mut q = VecDeque::new();
        for id in 0..6u64 {
            insert_by_priority(&mut q, Request::new(id, vec![1], 1, "fp32"));
        }
        let policy = SchedPolicy { age_rate: 100.0, ..SchedPolicy::default() };
        age_queue(&mut q, &policy);
        let order: Vec<u64> = q.iter().map(|r| r.id).collect();
        assert_eq!(order, vec![0, 1, 2, 3, 4, 5]);
    }

    #[test]
    fn sched_policy_queue_bound() {
        let unbounded = SchedPolicy::default();
        assert!(!unbounded.queue_full(1_000_000));
        let bounded = SchedPolicy { max_queue: 4, ..SchedPolicy::default() };
        assert!(!bounded.queue_full(3));
        assert!(bounded.queue_full(4));
        assert!(bounded.queue_full(5));
    }

    #[test]
    fn spec_stats_acceptance() {
        let s = SpecStats { drafted: 8, accepted: 6, rounds: 2 };
        assert!((s.acceptance_rate() - 0.75).abs() < 1e-12);
        let none = SpecStats { drafted: 0, accepted: 0, rounds: 0 };
        assert_eq!(none.acceptance_rate(), 0.0);
    }
}
