//! Layer-3 serving coordinator: request router, chunked-prefill scheduler,
//! dynamic decode batcher, the SSM state manager, and the speculative
//! decoding engine.
//!
//! Mamba serving differs from transformer serving in one decisive way: the
//! per-request state is a *fixed-size* recurrent state (conv window + SSM
//! hidden state) instead of a sequence-length-proportional KV cache, so
//! admission control is O(1) per request and batches never fragment memory.
//! The coordinator exploits that: a flat [`state::StatePool`] of equal-size
//! slots, a [`batcher::DecodeBatcher`] that packs active sequences into the
//! AOT-compiled batch buckets, and a [`scheduler::Engine`] that prefills
//! prompts in bucket-sized chunks (exact chunked prefill — validated
//! bit-exact against whole-sequence prefill) before handing them to the
//! decode loop.  All compute goes through the
//! [`crate::backend::InferenceBackend`] trait — the engines are identical
//! over the PJRT artifacts and the artifact-free native model, and any
//! future backend inherits them unchanged.
//!
//! [`router::serve_pool`] scales this out: N worker threads, each owning
//! its own backend (built from a factory closure) and its own engine,
//! behind the capacity-aware [`router::Router`] with a shared ingress
//! channel and per-worker [`metrics::Metrics`] merged into one aggregate.
//! Worker count changes throughput, never tokens — the fan-out is
//! token-exact with a single worker.
//!
//! The constant-size state also makes **prompt caching** O(state) instead
//! of O(tokens): both engines optionally attach a shared
//! [`crate::statecache::StateCache`] (`Engine::with_cache`,
//! `SpecEngine::with_cache`, [`PoolConfig::with_cache`] for the pool) that
//! stores bucket-aligned prefix snapshots during admission and per-session
//! end-of-turn states at retire ([`request::Request::session_id`]), so
//! shared system prompts and multi-turn conversations skip their
//! redundant prefill — bit-exact with the uncached path for prefix hits.
//!
//! The client-facing contract is a **streaming request lifecycle**: every
//! submit path returns a [`request::SubmitHandle`] with a per-request
//! [`request::Event`] stream (`FirstToken`, per-token `Token`, terminal
//! `Finished` carrying a [`request::FinishReason`]) plus `cancel()`;
//! [`request::Request`] takes an optional deadline and a priority.  Both
//! engines check cancellation and deadline every step and retire through
//! the normal path, so an abandoned request frees its constant-size state
//! slot immediately and still publishes its session-cache entry.  Batch
//! collection is unchanged — `finished` vectors and the pool's aggregate
//! `results` channel receive every terminal result.
//!
//! Everything above is observable live: [`metrics::Metrics`] optionally
//! write through to [`crate::obs`] telemetry cells
//! (`Engine::with_telemetry`, [`PoolConfig`]`::hub`), so a Prometheus
//! scrape or the periodic status line reads the same counters and
//! log-bucketed histograms the end-of-run report merges — and a shared
//! [`crate::obs::TraceSink`] (`Engine::with_trace`, [`PoolConfig`]`::trace`)
//! records each request's queued → admitted → prefill-chunk →
//! first-token → retire lifecycle as a Chrome-trace span tree.
//!
//! The second serving mode is speculative: [`speculative::SpecEngine`]
//! drives a draft-k / verify-1 loop in which the quantized `fastmamba`
//! variant drafts candidate tokens with single-token decode steps (on any
//! backend — drafter and verifier pair freely) and the
//! `fp32` verifier scores the whole draft window in one chunked-prefill
//! style call.  The recurrent-state problem this creates (rejected drafts
//! must un-happen) is solved by versioned snapshots in
//! [`state::StatePool`]: checkpoint before each draft step, roll back to
//! the commit point in O(state) on rejection — no token is ever
//! recomputed.  The output is token-exact with plain greedy fp32 decoding;
//! [`metrics::Metrics`] tracks draft acceptance alongside the batching
//! efficiency counters.

pub(crate) mod admission;
pub mod batcher;
pub mod metrics;
pub mod request;
pub mod router;
pub mod sampler;
pub mod scheduler;
pub mod speculative;
pub mod state;

pub use batcher::DecodeBatcher;
pub use metrics::{Metrics, WorkerStat};
pub use request::{
    CancelFlag, Event, FinishReason, FinishedRequest, Request, SchedPolicy, SpecStats,
    SubmitHandle,
};
pub use sampler::{Sampler, SamplingParams, StopMatcher};
pub use router::{serve_pool, serve_threaded, PoolConfig, PoolReport, Router, ServePool};
pub use scheduler::{Engine, EngineConfig};
pub use speculative::{SpecConfig, SpecEngine};
pub use state::{SnapshotId, StatePool};
