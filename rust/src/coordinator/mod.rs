//! Layer-3 serving coordinator: request router, chunked-prefill scheduler,
//! dynamic decode batcher, and the SSM state manager.
//!
//! Mamba serving differs from transformer serving in one decisive way: the
//! per-request state is a *fixed-size* recurrent state (conv window + SSM
//! hidden state) instead of a sequence-length-proportional KV cache, so
//! admission control is O(1) per request and batches never fragment memory.
//! The coordinator exploits that: a flat [`state::StatePool`] of equal-size
//! slots, a [`batcher::DecodeBatcher`] that packs active sequences into the
//! AOT-compiled batch buckets, and a [`scheduler::Engine`] that prefills
//! prompts in bucket-sized chunks (exact chunked prefill — validated
//! bit-exact against whole-sequence prefill) before handing them to the
//! decode loop.  All compute goes through [`crate::runtime::Runtime`].

pub mod batcher;
pub mod metrics;
pub mod request;
pub mod router;
pub mod scheduler;
pub mod state;

pub use batcher::DecodeBatcher;
pub use metrics::Metrics;
pub use request::{FinishedRequest, Request};
pub use router::Router;
pub use scheduler::{Engine, EngineConfig};
pub use state::StatePool;
