//! Request router and the multi-worker serving pool.
//!
//! [`Router`] spreads incoming requests over workers by least outstanding
//! load (state-slot aware — the Mamba serving advantage: a worker's
//! remaining capacity is exactly `capacity - in_use`, no sequence-length
//! estimation needed).
//!
//! [`serve_pool`] fans the serving engine out to N worker threads behind
//! that policy.  Each worker **constructs** its own backend from the
//! factory closure rather than borrowing one (PJRT clients are not Sync —
//! exactly like a real deployment where each worker process owns a
//! device), runs its own [`Engine`] (or [`SpecEngine`] when
//! [`PoolConfig::spec`] is set), and reports completions back to a
//! dispatcher that owns the [`Router`], tracks per-worker outstanding
//! load, and forwards results to the shared results channel.  Ingress is
//! one shared submission channel; dropping it (or calling
//! [`ServePool::finish`]) drains every worker and merges their
//! [`Metrics`] into one aggregate with per-worker roll-ups.
//!
//! [`SpecEngine`]: super::speculative::SpecEngine

use std::collections::VecDeque;
use std::sync::mpsc;
use std::sync::Arc;
use std::thread;
use std::time::Duration;

use anyhow::{anyhow, Result};

use crate::backend::InferenceBackend;
use crate::obs::flight::DISPATCHER_LANE;
use crate::obs::trace::TraceCtx;
use crate::obs::{Counter, FlightCtx, FlightKind, Telemetry, TelemetryHub, TraceSink};
use crate::statecache::StateCache;
use crate::util::json;

use super::metrics::{Metrics, WorkerStat};
use super::request::{
    age_queue, insert_by_priority, Event, FinishReason, FinishedRequest, Request,
    SchedPolicy, SubmitHandle,
};
use super::scheduler::{Engine, EngineConfig};
use super::speculative::{SpecConfig, SpecEngine};

/// Abstract view of a worker the router can place requests on.
pub trait Worker {
    /// currently held state slots
    fn load(&self) -> usize;
    /// total state slots
    fn capacity(&self) -> usize;
}

/// Least-loaded routing with capacity awareness.
#[derive(Debug, Default)]
pub struct Router {
    /// requests routed per worker (for accounting/tests)
    pub assignments: Vec<u64>,
}

impl Router {
    pub fn new(n_workers: usize) -> Self {
        Self { assignments: vec![0; n_workers] }
    }

    /// Pick the worker with the most free slots; `None` if all full.
    pub fn route<W: Worker>(&mut self, workers: &[W]) -> Option<usize> {
        let (mut best, mut best_free) = (None, 0usize);
        for (i, w) in workers.iter().enumerate() {
            let free = w.capacity().saturating_sub(w.load());
            if free > best_free {
                best = Some(i);
                best_free = free;
            }
        }
        if let Some(i) = best {
            self.assignments[i] += 1;
        }
        best
    }
}

/// Configuration of a [`serve_pool`] launch.
#[derive(Debug, Clone)]
pub struct PoolConfig {
    /// per-worker engine configuration (plain batched-greedy mode)
    pub engine: EngineConfig,
    /// worker threads, one backend each
    pub n_workers: usize,
    /// when set, each worker runs a speculative [`SpecEngine`] (drafting
    /// and verifying on the worker's own backend) instead of the plain
    /// engine; `spec.max_active` then bounds the worker's concurrency
    pub spec: Option<SpecConfig>,
    /// shared SSM state cache: every worker's engine attaches this same
    /// `Arc`, so a prefix snapshot published by one worker's admission is
    /// hit by every other worker (interior sharded locking — no
    /// coordination through the dispatcher)
    pub cache: Option<Arc<StateCache>>,
    /// live telemetry hub: each worker registers its own [`Telemetry`]
    /// cell (label = worker id) and the dispatcher registers one for the
    /// requests it resolves itself, so a `/metrics` scrape mid-run reads
    /// the same cells the end-of-run report merges
    pub hub: Option<Arc<TelemetryHub>>,
    /// span-trace sink shared by every worker: the dispatcher opens each
    /// request's envelope at ingress, the owning worker fills in
    /// admission/prefill/decode spans and closes it at retire
    pub trace: Option<Arc<TraceSink>>,
    /// overload policy.  `max_queue` bounds the *dispatcher backlog* (the
    /// pool's single admission point — worker queues are already bounded
    /// by routing capacity, so workers run with shedding disabled);
    /// `age_rate` ages both the backlog and every worker's pending queue;
    /// `preempt_threshold` applies inside each worker's engine.
    pub sched: SchedPolicy,
    /// `HOST:PORT` addresses of remote worker processes (started with
    /// `serve --worker-mode`) to adopt into the pool alongside the local
    /// threads.  Each address is connected and handshaken synchronously at
    /// pool start; its advertised capacity feeds the same router, and a
    /// lost connection takes the established worker-death path (re-route
    /// to survivors, zero results lost).
    pub remote: Vec<String>,
}

impl Default for PoolConfig {
    fn default() -> Self {
        Self {
            engine: EngineConfig::default(),
            n_workers: 1,
            spec: None,
            cache: None,
            hub: None,
            trace: None,
            sched: SchedPolicy::default(),
            remote: Vec::new(),
        }
    }
}

impl PoolConfig {
    /// State-slot capacity the router budgets per worker.
    pub fn capacity_per_worker(&self) -> usize {
        match &self.spec {
            Some(s) => s.max_active,
            None => self.engine.max_active,
        }
    }

    /// Attach a shared state cache to every worker.
    pub fn with_cache(mut self, cache: Arc<StateCache>) -> Self {
        self.cache = Some(cache);
        self
    }

    /// Attach a telemetry hub for live (mid-run) metric reads.
    pub fn with_telemetry_hub(mut self, hub: Arc<TelemetryHub>) -> Self {
        self.hub = Some(hub);
        self
    }

    /// Attach a span-trace sink shared by the dispatcher and all workers.
    pub fn with_trace(mut self, sink: Arc<TraceSink>) -> Self {
        self.trace = Some(sink);
        self
    }

    /// Attach an overload policy (aging, preemption, bounded backlog).
    pub fn with_sched(mut self, sched: SchedPolicy) -> Self {
        self.sched = sched;
        self
    }

    /// Adopt remote worker processes at these `HOST:PORT` addresses.
    pub fn with_remote_workers(mut self, addrs: Vec<String>) -> Self {
        self.remote = addrs;
        self
    }
}

/// What the pool measured, returned by [`ServePool::finish`].
#[derive(Debug)]
pub struct PoolReport {
    /// all workers' metrics folded into one aggregate (wall clock spans
    /// the earliest worker start to the latest stop), with
    /// [`Metrics::worker_stats`] carrying the per-worker roll-ups
    pub merged: Metrics,
    /// each worker's own metrics, indexed by worker id
    pub per_worker: Vec<Metrics>,
    /// requests routed per worker (the router's accounting)
    pub assignments: Vec<u64>,
    /// highest outstanding (dispatched, not yet finished) count per
    /// worker — never exceeds that worker's entry in
    /// [`PoolReport::capacities`]
    pub load_peak: Vec<usize>,
    /// the uniform local-worker capacity (remote workers may advertise a
    /// different one; see [`PoolReport::capacities`])
    pub capacity_per_worker: usize,
    /// per-worker state-slot capacity the router budgeted: local workers
    /// first, then one entry per adopted remote worker
    pub capacities: Vec<usize>,
    /// worker failures (dead backends, engine errors).  A dead worker's
    /// genuinely unfinished requests re-route to the survivors (its own
    /// `Done` results always arrive first, so nothing duplicates; a
    /// re-served request re-streams from token index 0).  If *every*
    /// worker dies, each remaining request is finished with
    /// [`FinishReason::WorkerDied`] — terminal event + aggregate result,
    /// empty output — and the pool shuts down.  Empty on a clean run.
    /// Submissions still in flight through the ingress channel when an
    /// all-dead pool shuts down are lost without being counted.
    pub errors: Vec<String>,
}

/// Handle to a running worker pool: submit requests, read results, then
/// [`ServePool::finish`] to drain, join, and collect the [`PoolReport`].
pub struct ServePool {
    submit: Option<mpsc::Sender<Request>>,
    pub results: mpsc::Receiver<FinishedRequest>,
    dispatcher: Option<thread::JoinHandle<Result<PoolReport>>>,
    pub n_workers: usize,
}

impl ServePool {
    /// Queue a request for dispatch and return its streaming
    /// [`SubmitHandle`].  The owning worker emits events in real time;
    /// `cancel()` travels with the request (the flag is shared by every
    /// clone, including the dispatcher's outstanding copy), so whichever
    /// worker holds the request observes it at its next engine step and
    /// frees the state slot immediately.  The terminal `Finished` event
    /// also feeds the aggregate [`ServePool::results`] channel, so batch
    /// collectors keep working unchanged.
    pub fn submit(&self, mut req: Request) -> Result<SubmitHandle> {
        let handle = req.attach_events();
        self.submit
            .as_ref()
            .ok_or_else(|| anyhow!("pool ingress already closed"))?
            .send(req)
            .map_err(|_| anyhow!("pool dispatcher is gone"))?;
        Ok(handle)
    }

    /// Clone the ingress channel (for concurrent submitters).
    ///
    /// End-of-input is signalled by hangup: **every** clone handed out
    /// here must be dropped (in addition to the pool's own handle via
    /// [`ServePool::finish`] / [`ServePool::close_ingress`]) before the
    /// pool can drain — `finish` blocks until the last submitter hangs up.
    pub fn sender(&self) -> mpsc::Sender<Request> {
        self.submit.clone().expect("pool ingress already closed")
    }

    /// Close ingress without joining (outstanding requests still finish).
    pub fn close_ingress(&mut self) {
        self.submit = None;
    }

    /// Close ingress, wait for every dispatched request to complete, join
    /// all workers, and return the merged report.  Read everything you
    /// want from [`ServePool::results`] first: `finish` consumes the
    /// pool, so results still buffered in the channel are discarded.
    ///
    /// Blocks until all work drains, which requires every
    /// [`ServePool::sender`] clone to have been dropped (see there).
    pub fn finish(mut self) -> Result<PoolReport> {
        self.submit = None; // end-of-input: forwarder signals the dispatcher
        let handle = self.dispatcher.take().expect("finish called once");
        match handle.join() {
            Ok(report) => report,
            Err(_) => Err(anyhow!("pool dispatcher panicked")),
        }
    }
}

/// Dispatcher-side view of a worker (dead workers advertise capacity 0 so
/// the router can never pick them).
struct WorkerView {
    load: usize,
    capacity: usize,
}

impl Worker for WorkerView {
    fn load(&self) -> usize {
        self.load
    }
    fn capacity(&self) -> usize {
        self.capacity
    }
}

pub(crate) enum Msg {
    Incoming(Request),
    IngressClosed,
    Done { worker: usize, fin: FinishedRequest },
    WorkerDead { worker: usize, error: String },
}

/// Either serving engine, so one worker loop drives both modes.  Also the
/// engine a remote worker process pumps ([`crate::remote::worker`]) — the
/// wire protocol changes transport, never serving behavior.
pub(crate) enum WorkerEngine<'be> {
    Plain(Engine<'be>),
    Spec(SpecEngine<'be>),
}

impl<'be> WorkerEngine<'be> {
    /// Build the engine a pool worker (in-process or remote) runs: plain
    /// or speculative per the config, shared cache attached, and the pool
    /// policy with shedding disabled — the dispatcher backlog is the
    /// single admission point, and the router never sends a worker more
    /// than its capacity anyway.
    pub(crate) fn build(be: &'be dyn InferenceBackend, cfg: &PoolConfig) -> Self {
        let wpolicy = SchedPolicy { max_queue: 0, ..cfg.sched.clone() };
        match &cfg.spec {
            Some(sc) => {
                let mut e = SpecEngine::new(be, sc.clone()).with_policy(wpolicy);
                if let Some(c) = &cfg.cache {
                    e = e.with_cache(Arc::clone(c));
                }
                Self::Spec(e)
            }
            None => {
                let mut e = Engine::new(be, cfg.engine.clone()).with_policy(wpolicy);
                if let Some(c) = &cfg.cache {
                    e = e.with_cache(Arc::clone(c));
                }
                Self::Plain(e)
            }
        }
    }

    pub(crate) fn submit(&mut self, req: Request) {
        // enqueue, not submit: the event channel was attached by
        // ServePool::submit before the request crossed into this worker
        match self {
            Self::Plain(e) => e.enqueue(req),
            Self::Spec(e) => e.enqueue(req),
        }
    }

    pub(crate) fn idle(&self) -> bool {
        self.load() == 0
    }

    /// pending + active requests currently held.
    pub(crate) fn load(&self) -> usize {
        match self {
            Self::Plain(e) => e.n_pending() + e.n_active(),
            Self::Spec(e) => e.n_pending() + e.n_active(),
        }
    }

    pub(crate) fn step(&mut self) -> Result<()> {
        match self {
            Self::Plain(e) => e.step(),
            Self::Spec(e) => e.step(),
        }
    }

    pub(crate) fn drain_finished(&mut self) -> Vec<FinishedRequest> {
        match self {
            Self::Plain(e) => e.finished.drain(..).collect(),
            Self::Spec(e) => e.finished.drain(..).collect(),
        }
    }

    pub(crate) fn metrics_mut(&mut self) -> &mut Metrics {
        match self {
            Self::Plain(e) => &mut e.metrics,
            Self::Spec(e) => &mut e.metrics,
        }
    }

    fn set_trace(&mut self, ctx: TraceCtx) {
        match self {
            Self::Plain(e) => e.set_trace(ctx),
            Self::Spec(e) => e.set_trace(ctx),
        }
    }

    fn set_flight(&mut self, ctx: FlightCtx) {
        match self {
            Self::Plain(e) => e.set_flight(ctx),
            Self::Spec(e) => e.set_flight(ctx),
        }
    }

    fn into_metrics(self) -> Metrics {
        match self {
            Self::Plain(e) => e.metrics,
            Self::Spec(e) => e.metrics,
        }
    }
}

/// Sends `Msg::WorkerDead` when dropped while armed, so the dispatcher
/// learns of *every* abnormal worker exit — error returns AND panics
/// (unwind drops the guard).  Because the notice travels the same channel
/// as the worker's `Done` messages, it is guaranteed to arrive after all
/// of them: the dispatcher's outstanding list is exact at burial time.
pub(crate) struct DeathNotice {
    pub(crate) worker: usize,
    pub(crate) pool_tx: mpsc::Sender<Msg>,
    pub(crate) error: String,
    pub(crate) armed: bool,
}

impl Drop for DeathNotice {
    fn drop(&mut self) {
        if self.armed {
            let _ = self.pool_tx.send(Msg::WorkerDead {
                worker: self.worker,
                error: std::mem::take(&mut self.error),
            });
        }
    }
}

/// One worker thread: build the backend, run the engine until ingress
/// disconnects and all work drains, return the engine's metrics.
fn run_worker<F>(
    id: usize,
    make_backend: Arc<F>,
    cfg: PoolConfig,
    rx: mpsc::Receiver<Request>,
    pool_tx: mpsc::Sender<Msg>,
) -> Result<Metrics>
where
    F: Fn() -> Result<Box<dyn InferenceBackend>>,
{
    let mut notice = DeathNotice {
        worker: id,
        pool_tx: pool_tx.clone(),
        error: "worker panicked".to_string(),
        armed: true,
    };
    let be = match make_backend() {
        Ok(be) => be,
        Err(e) => {
            notice.error = format!("backend construction failed: {e}");
            return Err(e); // the death notice fires on drop
        }
    };
    let mut engine = WorkerEngine::build(be.as_ref(), &cfg);
    if let Some(hub) = &cfg.hub {
        engine
            .metrics_mut()
            .attach_telemetry(hub.register(&id.to_string()));
        // lifecycle transitions land in the hub's shared flight recorder
        // under this worker's lane
        engine.set_flight(FlightCtx::new(Arc::clone(hub.flight()), id as u32));
    }
    if let Some(sink) = &cfg.trace {
        // the dispatcher opened the request envelope at ingress; the
        // worker only fills in admission/prefill/decode spans and closes it
        let mut ctx = TraceCtx::new(Arc::clone(sink), id as u32);
        ctx.record_queued = false;
        engine.set_trace(ctx);
    }
    engine.metrics_mut().start();
    loop {
        // drain whatever is queued without blocking; block only if idle
        let mut disconnected = false;
        loop {
            match rx.try_recv() {
                Ok(r) => engine.submit(r),
                Err(mpsc::TryRecvError::Empty) => break,
                Err(mpsc::TryRecvError::Disconnected) => {
                    disconnected = true;
                    break;
                }
            }
        }
        if engine.idle() {
            if disconnected {
                break;
            }
            match rx.recv() {
                Ok(r) => engine.submit(r),
                Err(_) => break,
            }
        }
        if let Err(e) = engine.step() {
            notice.error = format!("engine step failed: {e}");
            return Err(e); // the death notice fires on drop
        }
        for f in engine.drain_finished() {
            let _ = pool_tx.send(Msg::Done { worker: id, fin: f });
        }
    }
    notice.armed = false; // clean drain: no death notice
    engine.metrics_mut().stop();
    Ok(engine.into_metrics())
}

#[allow(clippy::too_many_arguments)]
fn dispatch(
    n: usize,
    capacities: Vec<usize>,
    worker_tx: Vec<mpsc::Sender<Request>>,
    handles: Vec<thread::JoinHandle<Result<Metrics>>>,
    pool_rx: mpsc::Receiver<Msg>,
    tx_done: mpsc::Sender<FinishedRequest>,
    dtel: Option<Arc<Telemetry>>,
    trace: Option<Arc<TraceSink>>,
    sched: SchedPolicy,
    flight: Option<FlightCtx>,
) -> Result<PoolReport> {
    let mut router = Router::new(n);
    // the dispatcher keeps a copy of every request a worker currently
    // holds: a worker's load IS its outstanding list, and when a worker
    // dies its unfinished requests re-route to the survivors (a worker's
    // own Done messages always precede its WorkerDead on the same channel,
    // so the list is exact — re-routing never duplicates a result)
    let mut outstanding: Vec<Vec<Request>> = vec![Vec::new(); n];
    let mut load_peak = vec![0usize; n];
    let mut alive = vec![true; n];
    let mut backlog: VecDeque<Request> = VecDeque::new();
    let mut ingress_open = true;
    let mut errors: Vec<String> = Vec::new();
    // requests the dispatcher itself resolved (cancelled/expired while
    // queued, or terminally lost to worker death) — folded into the merged
    // metrics so the aggregate accounts for every submitted request
    let mut dispatcher = Metrics::default();
    // the status slot is published directly (not through Metrics), so keep
    // a handle alongside the write-through attachment
    let dstatus = dtel.clone();
    if let Some(t) = dtel {
        dispatcher.attach_telemetry(t);
    }
    // the dispatcher's live status: pool liveness (`/healthz`, `/readyz`)
    // and the `/statusz` dispatcher row both read this slot, so it must be
    // (re)published before every blocking wait — an idle pool still
    // answers readiness probes from its latest publish
    let publish_status =
        |alive: &[bool], backlog_len: usize, dispatched: u64| {
            if let Some(t) = &dstatus {
                let n_alive = alive.iter().filter(|a| **a).count();
                t.set_status(json::obj(vec![
                    ("role", json::s("dispatcher")),
                    ("workers_alive", json::num(n_alive as f64)),
                    ("backlog", json::num(backlog_len as f64)),
                    ("max_queue", json::num(sched.max_queue as f64)),
                    ("dispatched_total", json::num(dispatched as f64)),
                ]));
            }
        };
    // the dispatcher opens each sampled request's trace envelope at
    // ingress (workers run with `record_queued = false`), so queue time
    // shows up inside the request span
    let open_envelope = |req: &Request| {
        if let Some(s) = &trace {
            if s.sampled(req.id) {
                s.begin_request(req.id, req.prompt.len(), req.priority);
            }
        }
    };
    let close_envelope = |id: u64, reason: FinishReason| {
        if let Some(s) = &trace {
            if s.sampled(id) {
                s.end_request(id, &format!("{reason:?}"), 0);
            }
        }
    };

    /// Terminal result for a request that never finished on a worker.
    fn dropped_fin(req: &Request, reason: FinishReason) -> FinishedRequest {
        FinishedRequest {
            id: req.id,
            prompt_len: req.prompt.len(),
            generated: Vec::new(),
            finish_reason: reason,
            ttft_s: 0.0,
            total_s: req.submitted_at.elapsed().as_secs_f64(),
            spec: None,
        }
    }

    fn bury(
        w: usize,
        alive: &mut [bool],
        outstanding: &mut [Vec<Request>],
        backlog: &mut VecDeque<Request>,
        errors: &mut Vec<String>,
    ) {
        alive[w] = false;
        // its own Done messages always precede the WorkerDead notice on the
        // shared channel, so everything still listed here is genuinely
        // unfinished — re-routing never duplicates a result
        let lost = std::mem::take(&mut outstanding[w]);
        if !lost.is_empty() {
            errors.push(format!(
                "worker {w} died holding {} request(s); re-routing",
                lost.len()
            ));
            for r in lost {
                insert_by_priority(backlog, r);
            }
        }
    }

    loop {
        // resolve cancelled / past-deadline backlog entries without ever
        // occupying a worker: terminal event + aggregate result right here.
        // (Requests already on a worker are the worker engine's job — the
        // shared flag travels with the request, so the owning worker sees
        // the cancellation at its next step and frees the slot.)
        let mut i = 0;
        while i < backlog.len() {
            if let Some(reason) = backlog[i].lifecycle_reason() {
                let req = backlog.remove(i).expect("index in bounds");
                let fin = dropped_fin(&req, reason);
                dispatcher.note_finish_reason(reason);
                dispatcher.count(Counter::RequestsCompleted, 1);
                // no latency sample: the histogram holds requests that
                // actually completed on a worker, not dispatcher-resolved
                // drops (a dropped request's near-zero "latency" would
                // deflate every percentile under load)
                dispatcher.count(Counter::RequestsDropped, 1);
                if let Some(f) = &flight {
                    f.record(
                        req.id,
                        FlightKind::Finish,
                        format!("{reason:?} unadmitted tokens=0"),
                    );
                }
                close_envelope(fin.id, reason);
                req.emit(Event::Finished(fin.clone()));
                let _ = tx_done.send(fin);
            } else {
                i += 1;
            }
        }
        dispatcher.note_queue_depth(backlog.len());

        // priority aging: re-sort the backlog by effective priority so a
        // starved low-priority request eventually places ahead of fresh
        // high-priority arrivals
        if age_queue(&mut backlog, &sched) {
            dispatcher.count(Counter::AgingReorders, 1);
        }

        // place as much backlog as worker capacity allows; `route` returning
        // None means every live worker is at capacity — wait for a `Done`
        while !backlog.is_empty() {
            let views: Vec<WorkerView> = (0..n)
                .map(|i| WorkerView {
                    load: outstanding[i].len(),
                    capacity: if alive[i] { capacities[i] } else { 0 },
                })
                .collect();
            let Some(w) = router.route(&views) else { break };
            let req = backlog.pop_front().unwrap();
            match worker_tx[w].send(req.clone()) {
                Ok(()) => {
                    if let Some(f) = &flight {
                        f.record(req.id, FlightKind::Dispatch, format!("worker={w}"));
                    }
                    outstanding[w].push(req);
                    load_peak[w] = load_peak[w].max(outstanding[w].len());
                }
                Err(mpsc::SendError(_)) => {
                    // the worker's channel is gone, so its death notice is
                    // already in flight — and ordered AFTER any Done messages
                    // still queued from it.  Burying it here would re-route
                    // requests whose results are about to arrive (duplicates),
                    // so only undo this routing decision and stop selecting
                    // the worker; the WorkerDead message does the burial.
                    router.assignments[w] -= 1;
                    alive[w] = false;
                    backlog.push_front(req);
                }
            }
        }

        publish_status(
            &alive,
            backlog.len(),
            router.assignments.iter().sum::<u64>(),
        );

        if !alive.iter().any(|a| *a) {
            // nothing can make progress; drain the queue — forwarding
            // results the dead workers already computed and recording any
            // still-queued death notices — then finish every remaining
            // request with `FinishReason::WorkerDied` (terminal event +
            // aggregate result, empty output) so stream consumers and
            // result readers unblock instead of hanging
            while let Ok(msg) = pool_rx.try_recv() {
                match msg {
                    Msg::Done { worker, fin } => {
                        if let Some(pos) =
                            outstanding[worker].iter().position(|r| r.id == fin.id)
                        {
                            outstanding[worker].remove(pos);
                        }
                        let _ = tx_done.send(fin);
                    }
                    Msg::WorkerDead { worker, error } => {
                        if let Some(f) = &flight {
                            f.record(0, FlightKind::WorkerDeath, format!("worker={worker} {error}"));
                        }
                        errors.push(format!("worker {worker}: {error}"));
                        bury(worker, &mut alive, &mut outstanding, &mut backlog,
                             &mut errors);
                    }
                    Msg::Incoming(req) => {
                        open_envelope(&req);
                        insert_by_priority(&mut backlog, req);
                    }
                    Msg::IngressClosed => {}
                }
            }
            let mut lost = 0usize;
            for req in backlog
                .drain(..)
                .chain(outstanding.iter_mut().flat_map(|o| o.drain(..)))
            {
                lost += 1;
                let fin = dropped_fin(&req, FinishReason::WorkerDied);
                if let Some(f) = &flight {
                    f.record(req.id, FlightKind::Finish, "WorkerDied unadmitted tokens=0");
                }
                dispatcher.count(Counter::RequestsCompleted, 1);
                // dropped, not completed: no latency sample (see the
                // backlog lifecycle sweep above)
                dispatcher.count(Counter::RequestsDropped, 1);
                close_envelope(fin.id, FinishReason::WorkerDied);
                req.emit(Event::Finished(fin.clone()));
                let _ = tx_done.send(fin);
            }
            if lost > 0 {
                errors.push(format!(
                    "{lost} request(s) finished with WorkerDied: every worker died"
                ));
            }
            break;
        }
        if !ingress_open
            && backlog.is_empty()
            && outstanding.iter().all(|o| o.is_empty())
        {
            break;
        }

        // with queued requests waiting, wake periodically even if no worker
        // traffic arrives, so the lifecycle sweep can resolve a backlog
        // cancellation / deadline expiry promptly instead of only at the
        // next Done message
        let msg = if backlog.is_empty() {
            pool_rx.recv().map_err(|_| ())
        } else {
            match pool_rx.recv_timeout(Duration::from_millis(20)) {
                Ok(m) => Ok(m),
                Err(mpsc::RecvTimeoutError::Timeout) => continue, // re-run the sweep
                Err(mpsc::RecvTimeoutError::Disconnected) => Err(()),
            }
        };
        match msg {
            Ok(Msg::Incoming(req)) => {
                open_envelope(&req);
                // admission control at the pool's single admission point:
                // a full backlog sheds the arrival with a retriable
                // terminal event and no latency sample
                if sched.queue_full(backlog.len()) {
                    let fin = dropped_fin(&req, FinishReason::Overloaded);
                    dispatcher.note_finish_reason(FinishReason::Overloaded);
                    dispatcher.count(Counter::RequestsCompleted, 1);
                    if let Some(s) = &trace {
                        if s.sampled(fin.id) {
                            s.instant(fin.id, "shed", Vec::new());
                        }
                    }
                    if let Some(f) = &flight {
                        f.record(
                            req.id,
                            FlightKind::Shed,
                            format!("backlog at shed threshold {}", sched.max_queue),
                        );
                        f.record(req.id, FlightKind::Finish, "Overloaded unadmitted tokens=0");
                    }
                    close_envelope(fin.id, FinishReason::Overloaded);
                    req.emit(Event::Finished(fin.clone()));
                    let _ = tx_done.send(fin);
                } else {
                    insert_by_priority(&mut backlog, req);
                }
            }
            Ok(Msg::IngressClosed) => ingress_open = false,
            Ok(Msg::Done { worker, fin }) => {
                if let Some(pos) =
                    outstanding[worker].iter().position(|r| r.id == fin.id)
                {
                    outstanding[worker].remove(pos);
                }
                let _ = tx_done.send(fin);
            }
            Ok(Msg::WorkerDead { worker, error }) => {
                if let Some(f) = &flight {
                    f.record(0, FlightKind::WorkerDeath, format!("worker={worker} {error}"));
                }
                errors.push(format!("worker {worker}: {error}"));
                bury(worker, &mut alive, &mut outstanding, &mut backlog, &mut errors);
            }
            Err(()) => break, // every sender (forwarder + workers) is gone
        }
    }

    // end-of-input for the workers: drain and join
    drop(worker_tx);
    let mut per_worker: Vec<Metrics> = Vec::with_capacity(n);
    for (w, h) in handles.into_iter().enumerate() {
        match h.join() {
            Ok(Ok(m)) => per_worker.push(m),
            Ok(Err(_)) => per_worker.push(Metrics::default()), // already recorded
            Err(_) => {
                errors.push(format!("worker {w} panicked"));
                per_worker.push(Metrics::default());
            }
        }
    }
    let mut merged = Metrics::default();
    let mut stats = Vec::with_capacity(n);
    for m in &per_worker {
        merged.merge(m);
        stats.push(WorkerStat {
            requests_completed: m.requests_completed,
            tokens_generated: m.tokens_generated,
            queue_depth_peak: m.queue_depth_peak,
            utilization: m.utilization(),
            cache_hits: m.cache_hits,
            cache_tokens_saved: m.cache_tokens_saved,
            cancelled: m.cancelled_requests,
            deadline_expired: m.deadline_expired,
            tpot_p50_s: m.tpot_p50(),
        });
    }
    merged.worker_stats = stats;
    // fold in the requests the dispatcher resolved itself (queued
    // cancellations/expiries, worker-death drops) so the aggregate counts
    // every submitted request exactly once — including their latency
    // samples, so percentiles cover the same population as
    // requests_completed
    merged.merge(&dispatcher);
    Ok(PoolReport {
        merged,
        per_worker,
        assignments: router.assignments,
        load_peak,
        capacity_per_worker: capacities.iter().copied().max().unwrap_or(0),
        capacities,
        errors,
    })
}

/// Fan the serving engine out to `cfg.n_workers` threads behind the
/// capacity-aware [`Router`].  Each worker owns a backend built by
/// `make_backend`; the dispatcher never sends a worker more outstanding
/// requests than its state-slot capacity, so a worker's engine is always
/// admitting from a queue it can hold.
///
/// Remote worker processes listed in [`PoolConfig::remote`] join the same
/// router after the local threads: each address is connected and
/// handshaken here (synchronously, so its advertised capacity is known
/// before dispatch starts), then proxied by a thread that speaks the
/// [`crate::remote::proto`] wire protocol.  An address that fails to
/// connect joins dead — capacity 0, its death recorded through the normal
/// worker-death path — rather than failing the whole pool.
pub fn serve_pool<F>(make_backend: F, cfg: PoolConfig) -> ServePool
where
    F: Fn() -> Result<Box<dyn InferenceBackend>> + Send + Sync + 'static,
{
    let n_local = cfg.n_workers;
    let n = n_local + cfg.remote.len();
    assert!(n >= 1, "pool needs at least one local or remote worker");
    let local_capacity = cfg.capacity_per_worker();
    if n_local > 0 {
        assert!(local_capacity >= 1, "worker capacity must be >= 1");
    }
    let make = Arc::new(make_backend);

    let (tx_req, rx_req) = mpsc::channel::<Request>();
    let (tx_done, rx_done) = mpsc::channel::<FinishedRequest>();
    let (pool_tx, pool_rx) = mpsc::channel::<Msg>();

    let dtel = cfg.hub.as_ref().map(|h| h.register("dispatcher"));
    let dtrace = cfg.trace.as_ref().map(Arc::clone);
    let dsched = cfg.sched.clone();
    // dispatcher-side flight lane: worker ids are 0..n, so the dispatcher
    // writes under a reserved sentinel lane
    let dflight = cfg
        .hub
        .as_ref()
        .map(|h| FlightCtx::new(Arc::clone(h.flight()), DISPATCHER_LANE));
    if let (Some(hub), Some(cache)) = (&cfg.hub, &cfg.cache) {
        hub.attach_cache(Arc::clone(cache));
    }

    // ingress forwarder: bridges the public Sender<Request> into the
    // dispatcher's message stream and signals end-of-input when every
    // submitter handle is dropped
    {
        let pool_tx = pool_tx.clone();
        thread::spawn(move || {
            for r in rx_req {
                if pool_tx.send(Msg::Incoming(r)).is_err() {
                    return;
                }
            }
            let _ = pool_tx.send(Msg::IngressClosed);
        });
    }

    let mut worker_tx = Vec::with_capacity(n);
    let mut handles = Vec::with_capacity(n);
    let mut capacities = vec![local_capacity; n_local];
    for id in 0..n_local {
        let (tx, rx) = mpsc::channel::<Request>();
        worker_tx.push(tx);
        let make = Arc::clone(&make);
        let wcfg = cfg.clone();
        let ptx = pool_tx.clone();
        handles.push(thread::spawn(move || run_worker(id, make, wcfg, rx, ptx)));
    }
    // remote workers take the indices after the locals; connect + handshake
    // now so each one's advertised capacity is budgeted before dispatch
    for (ri, addr) in cfg.remote.iter().enumerate() {
        let id = n_local + ri;
        let (tx, rx) = mpsc::channel::<Request>();
        worker_tx.push(tx);
        let ptx = pool_tx.clone();
        let tel = cfg.hub.as_ref().map(|h| h.register(&format!("remote:{addr}")));
        let transport = cfg.hub.as_ref().map(|h| h.register_remote(addr));
        match crate::remote::client::connect(addr, Duration::from_secs(10)) {
            Ok(conn) => {
                capacities.push(conn.capacity.max(1));
                handles.push(thread::spawn(move || {
                    crate::remote::client::run_remote(id, conn, rx, ptx, tel, transport)
                }));
            }
            Err(e) => {
                // dead on arrival: capacity 0 keeps the router off it, and
                // the armed notice records the death through the normal
                // worker-death path instead of failing the whole pool
                capacities.push(0);
                let error = format!("remote worker {addr}: {e}");
                if let Some(t) = &transport {
                    t.note_disconnect(0);
                }
                handles.push(thread::spawn(move || {
                    let _notice = DeathNotice {
                        worker: id,
                        pool_tx: ptx,
                        error: error.clone(),
                        armed: true,
                    };
                    Err(anyhow!(error))
                }));
            }
        }
    }
    drop(pool_tx);

    let dispatcher = thread::spawn(move || {
        dispatch(n, capacities, worker_tx, handles, pool_rx, tx_done, dtel, dtrace, dsched, dflight)
    });
    ServePool {
        submit: Some(tx_req),
        results: rx_done,
        dispatcher: Some(dispatcher),
        n_workers: n,
    }
}

/// Single-worker convenience wrapper over [`serve_pool`] — the original
/// threaded-serving entry point, now one instance of the pool.
pub fn serve_threaded<F>(make_backend: F, cfg: EngineConfig) -> ServePool
where
    F: Fn() -> Result<Box<dyn InferenceBackend>> + Send + Sync + 'static,
{
    serve_pool(
        make_backend,
        PoolConfig { engine: cfg, n_workers: 1, ..PoolConfig::default() },
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::NativeBackend;

    struct MockWorker {
        load: usize,
        cap: usize,
    }

    impl Worker for MockWorker {
        fn load(&self) -> usize {
            self.load
        }
        fn capacity(&self) -> usize {
            self.cap
        }
    }

    #[test]
    fn routes_to_least_loaded() {
        let mut r = Router::new(3);
        let ws = vec![
            MockWorker { load: 5, cap: 8 },
            MockWorker { load: 1, cap: 8 },
            MockWorker { load: 7, cap: 8 },
        ];
        assert_eq!(r.route(&ws), Some(1));
        assert_eq!(r.assignments, vec![0, 1, 0]);
    }

    #[test]
    fn none_when_all_full() {
        let mut r = Router::new(2);
        let ws = vec![
            MockWorker { load: 8, cap: 8 },
            MockWorker { load: 8, cap: 8 },
        ];
        assert_eq!(r.route(&ws), None);
    }

    #[test]
    fn capacity_aware_not_just_load() {
        // worker 0 has lower load but less free capacity
        let mut r = Router::new(2);
        let ws = vec![
            MockWorker { load: 1, cap: 2 },
            MockWorker { load: 3, cap: 16 },
        ];
        assert_eq!(r.route(&ws), Some(1));
    }

    #[test]
    fn serve_threaded_roundtrip_on_native_backend() {
        let pool = serve_threaded(
            || Ok(Box::new(NativeBackend::synthetic(3)) as Box<dyn InferenceBackend>),
            EngineConfig { max_active: 4, greedy_chunking: true },
        );
        let n = 3usize;
        for id in 0..n {
            let prompt: Vec<u32> =
                (0..24).map(|j| ((id * 97 + j * 13) % 512) as u32).collect();
            pool.submit(Request::new(id as u64, prompt, 5, "fp32")).unwrap();
        }
        let mut done = Vec::new();
        for _ in 0..n {
            let f = pool.results.recv().expect("worker produced a result");
            assert_eq!(f.generated.len(), 5);
            done.push(f.id);
        }
        done.sort_unstable();
        assert_eq!(done, vec![0, 1, 2]);
        let report = pool.finish().unwrap();
        assert!(report.errors.is_empty(), "{:?}", report.errors);
        assert_eq!(report.merged.requests_completed, 3);
        assert_eq!(report.assignments, vec![3]);
    }

    /// A deliberately small model so the 64-request stress trace runs fast
    /// in debug builds; same-seed construction gives every worker (and
    /// every pool) identical weights.
    fn micro_backend() -> NativeBackend {
        let mut cfg = crate::config::ModelConfig::tiny();
        cfg.name = "mamba2-micro".into();
        cfg.d_model = 64;
        cfg.n_layer = 2;
        cfg.d_state = 16;
        cfg.headdim = 16;
        cfg.vocab_size = 128;
        NativeBackend::new(crate::model::ModelWeights::random(&cfg, 9))
            .with_buckets(vec![8, 16, 32], vec![1, 2, 4])
    }

    fn stress_requests() -> Vec<Request> {
        // >= 64 mixed-length requests, deterministic, mixed variants
        let lens = [1usize, 3, 9, 17, 33, 48];
        (0..64usize)
            .map(|i| {
                let plen = lens[i % lens.len()];
                let prompt: Vec<u32> =
                    (0..plen).map(|j| ((i * 131 + j * 17) % 128) as u32).collect();
                let variant = if i % 3 == 0 { "fastmamba" } else { "fp32" };
                Request::new(i as u64, prompt, 2 + (i % 5), variant)
            })
            .collect()
    }

    #[test]
    fn sampled_pool_matches_single_engine_same_seed() {
        use crate::coordinator::sampler::SamplingParams;
        use crate::coordinator::scheduler::Engine;
        // sampled determinism across the fan-out: position-keyed draws
        // make the sampled stream independent of worker count and batch
        // packing, so a 4-worker pool reproduces the single engine exactly
        let sampled_reqs = || -> Vec<Request> {
            (0..12usize)
                .map(|i| {
                    let plen = [3usize, 9, 17, 33][i % 4];
                    let prompt: Vec<u32> =
                        (0..plen).map(|j| ((i * 131 + j * 17) % 128) as u32).collect();
                    Request::new(i as u64, prompt, 6, "fp32").with_sampling(
                        SamplingParams {
                            temperature: 1.0,
                            seed: 9000 + i as u64,
                            ..SamplingParams::default()
                        },
                    )
                })
                .collect()
        };
        let be = micro_backend();
        let mut eng = Engine::new(&be, EngineConfig { max_active: 4, greedy_chunking: true });
        for r in sampled_reqs() {
            eng.submit(r);
        }
        eng.run().unwrap();
        let mut want: Vec<(u64, Vec<u32>)> =
            eng.finished.iter().map(|f| (f.id, f.generated.clone())).collect();
        want.sort();

        let make = || Ok(Box::new(micro_backend()) as Box<dyn InferenceBackend>);
        let pool = serve_pool(
            make,
            PoolConfig {
                engine: EngineConfig { max_active: 4, greedy_chunking: true },
                n_workers: 4,
                ..PoolConfig::default()
            },
        );
        for r in sampled_reqs() {
            pool.submit(r).unwrap();
        }
        let mut got = Vec::new();
        for _ in 0..12 {
            let f = pool.results.recv().expect("pool result");
            got.push((f.id, f.generated));
        }
        got.sort();
        pool.finish().unwrap();
        assert_eq!(want, got, "4-worker sampled output != single engine");
    }

    #[test]
    fn multi_worker_pool_token_exact_and_capacity_bounded() {
        let make = || Ok(Box::new(micro_backend()) as Box<dyn InferenceBackend>);
        let n_reqs = stress_requests().len();

        let run = |n_workers: usize| -> (Vec<(u64, Vec<u32>)>, PoolReport) {
            let pool = serve_pool(
                make,
                PoolConfig {
                    engine: EngineConfig { max_active: 4, greedy_chunking: true },
                    n_workers,
                    spec: None,
                    cache: None,
                    ..PoolConfig::default()
                },
            );
            // rebuilt per run: Request::new stamps submitted_at, and reusing
            // clones would bleed the first run's wall time into the second
            // run's latency samples
            for r in stress_requests() {
                pool.submit(r).unwrap();
            }
            let mut got: Vec<(u64, Vec<u32>)> = (0..n_reqs)
                .map(|_| {
                    let f = pool.results.recv().expect("pool produced a result");
                    (f.id, f.generated)
                })
                .collect();
            let report = pool.finish().unwrap();
            got.sort();
            (got, report)
        };

        let (got1, rep1) = run(1);
        let (got4, rep4) = run(4);
        assert_eq!(got1, got4, "worker count changed generated tokens");
        assert!(rep1.errors.is_empty(), "{:?}", rep1.errors);
        assert!(rep4.errors.is_empty(), "{:?}", rep4.errors);

        // the router accounted for every request and never overcommitted
        assert_eq!(rep1.assignments.iter().sum::<u64>(), n_reqs as u64);
        assert_eq!(rep4.assignments.iter().sum::<u64>(), n_reqs as u64);
        assert_eq!(rep4.load_peak.len(), 4);
        for (w, &peak) in rep4.load_peak.iter().enumerate() {
            assert!(
                peak <= rep4.capacity_per_worker,
                "worker {w} exceeded capacity: peak {peak} > {}",
                rep4.capacity_per_worker
            );
        }
        // 64 requests over 4 capacity-4 workers: everyone saw traffic
        assert!(rep4.assignments.iter().all(|&a| a > 0), "{:?}", rep4.assignments);

        // merged metrics are the sum of the per-worker views
        assert_eq!(rep4.merged.requests_completed, n_reqs as u64);
        assert_eq!(rep4.merged.worker_stats.len(), 4);
        assert_eq!(
            rep4.merged.tokens_generated,
            rep4.per_worker.iter().map(|m| m.tokens_generated).sum::<u64>()
        );
        assert_eq!(
            rep4.merged.requests_completed,
            rep4.per_worker.iter().map(|m| m.requests_completed).sum::<u64>()
        );
    }

    #[test]
    fn shared_system_prompt_stress_cache_is_bit_identical() {
        use crate::model::Variant;
        use crate::statecache::{CacheConfig, StateCache};
        // 32 mixed-length requests sharing a 33-token system prompt,
        // cycling through ALL five variants, over 4 workers: the shared
        // state cache must change prefill work only — the pool's output
        // must be bit-identical with the cache off
        let make = || Ok(Box::new(micro_backend()) as Box<dyn InferenceBackend>);
        let make_reqs = || -> Vec<Request> {
            let sys: Vec<u32> = (0..33).map(|j| ((j * 7 + 5) % 128) as u32).collect();
            (0..32usize)
                .map(|i| {
                    let mut prompt = sys.clone();
                    prompt.extend((0..1 + (i % 11)).map(|j| ((i * 131 + j * 17) % 128) as u32));
                    let variant = Variant::ALL[i % 5].name();
                    Request::new(i as u64, prompt, 2 + (i % 4), variant)
                })
                .collect()
        };
        let n_reqs = make_reqs().len();

        let run = |cache: Option<Arc<StateCache>>| -> (Vec<(u64, Vec<u32>)>, PoolReport) {
            let pool = serve_pool(
                make,
                PoolConfig {
                    engine: EngineConfig { max_active: 4, greedy_chunking: true },
                    n_workers: 4,
                    spec: None,
                    cache,
                    ..PoolConfig::default()
                },
            );
            for r in make_reqs() {
                pool.submit(r).unwrap();
            }
            let mut got: Vec<(u64, Vec<u32>)> = (0..n_reqs)
                .map(|_| {
                    let f = pool.results.recv().expect("pool result");
                    (f.id, f.generated)
                })
                .collect();
            let report = pool.finish().unwrap();
            assert!(report.errors.is_empty(), "{:?}", report.errors);
            got.sort();
            (got, report)
        };

        let (off, rep_off) = run(None);
        assert_eq!(rep_off.merged.cache_hits + rep_off.merged.cache_misses, 0);

        let cache = Arc::new(StateCache::new(CacheConfig::default()));
        let (on, rep_on) = run(Some(Arc::clone(&cache)));
        assert_eq!(off, on, "state cache changed generated tokens");

        // every prompt's plan starts with the shared 32-token boundary, so
        // after each variant's first admission the rest hit it.  Workers
        // race on first admissions, so bound loosely: at most one miss per
        // (variant, worker) pair.
        let m = &rep_on.merged;
        assert!(m.cache_hits + m.cache_misses >= n_reqs as u64);
        assert!(m.cache_hits >= (n_reqs - 5 * 4) as u64, "{}", m.summary());
        assert!(m.cache_tokens_saved >= m.cache_hits * 32, "{}", m.summary());
        assert!(m.summary().contains("cache_hit="), "{}", m.summary());
        // the per-worker roll-ups carry the cache counters and sum to the
        // aggregate
        assert_eq!(m.worker_stats.len(), 4);
        assert_eq!(
            m.worker_stats.iter().map(|w| w.cache_hits).sum::<u64>(),
            m.cache_hits
        );
        assert_eq!(
            m.worker_stats.iter().map(|w| w.cache_tokens_saved).sum::<u64>(),
            m.cache_tokens_saved
        );
        // and the cache itself observed the traffic
        let stats = cache.stats();
        assert_eq!(stats.hits, m.cache_hits);
        assert!(stats.entries > 0);
        assert!(stats.bytes_resident > 0);
        assert!(stats.bytes_resident <= cache.max_bytes());
    }

    #[test]
    fn speculative_pool_matches_plain_greedy() {
        // SpecEngine workers behind the router must reproduce the plain
        // greedy fp32 outputs (token-exactness survives the fan-out)
        let make = || Ok(Box::new(micro_backend()) as Box<dyn InferenceBackend>);
        let make_reqs = || -> Vec<Request> {
            [9usize, 17, 20, 33]
                .iter()
                .enumerate()
                .map(|(i, &plen)| {
                    let prompt: Vec<u32> =
                        (0..plen).map(|j| ((i * 131 + j * 17) % 128) as u32).collect();
                    Request::new(i as u64, prompt, 4, "fp32")
                })
                .collect()
        };
        let n_reqs = make_reqs().len();

        let run = |spec: Option<SpecConfig>, n_workers: usize| {
            let pool = serve_pool(
                make,
                PoolConfig {
                    engine: EngineConfig { max_active: 2, greedy_chunking: true },
                    n_workers,
                    spec,
                    cache: None,
                    ..PoolConfig::default()
                },
            );
            for r in make_reqs() {
                pool.submit(r).unwrap();
            }
            let mut got: Vec<(u64, Vec<u32>)> = (0..n_reqs)
                .map(|_| {
                    let f = pool.results.recv().expect("pool produced a result");
                    (f.id, f.generated)
                })
                .collect();
            let report = pool.finish().unwrap();
            assert!(report.errors.is_empty(), "{:?}", report.errors);
            got.sort();
            got
        };

        let want = run(None, 1);
        let got = run(
            Some(SpecConfig { draft_k: 2, max_active: 2, ..SpecConfig::default() }),
            2,
        );
        assert_eq!(want, got, "speculative pool diverged from plain greedy");
    }

    /// Block (with a bound) until a handle's terminal event arrives.
    fn finished_within(h: &SubmitHandle, secs: u64) -> FinishedRequest {
        use std::time::Duration;
        loop {
            match h.next_event_timeout(Duration::from_secs(secs)) {
                Some(Event::Finished(f)) => return f,
                Some(_) => {}
                None => panic!("req {}: no terminal event within {secs}s", h.id()),
            }
        }
    }

    #[test]
    fn pool_streams_are_token_identical_to_batch_results() {
        use crate::model::Variant;
        // 4 workers, all five variants: every per-request stream must be
        // bit-identical to the batch result delivered on the aggregate
        // channel (which existing tests pin to the 1-worker engine output)
        let make = || Ok(Box::new(micro_backend()) as Box<dyn InferenceBackend>);
        let pool = serve_pool(
            make,
            PoolConfig {
                engine: EngineConfig { max_active: 4, greedy_chunking: true },
                n_workers: 4,
                spec: None,
                cache: None,
                ..PoolConfig::default()
            },
        );
        let n = 20usize;
        let mut handles = Vec::with_capacity(n);
        for i in 0..n {
            let plen = 5 + (i % 7) * 4;
            let prompt: Vec<u32> =
                (0..plen).map(|j| ((i * 131 + j * 17) % 128) as u32).collect();
            let variant = Variant::ALL[i % 5].name();
            handles
                .push(pool.submit(Request::new(i as u64, prompt, 3 + (i % 3), variant)).unwrap());
        }
        let mut results: Vec<FinishedRequest> =
            (0..n).map(|_| pool.results.recv().expect("pool result")).collect();
        let report = pool.finish().unwrap();
        assert!(report.errors.is_empty(), "{:?}", report.errors);
        results.sort_by_key(|f| f.id);
        for h in &handles {
            let want = &results[h.id() as usize];
            let mut toks = Vec::new();
            let mut first = false;
            let mut fin = None;
            while let Some(ev) = h.try_event() {
                match ev {
                    Event::FirstToken => {
                        assert!(toks.is_empty());
                        first = true;
                    }
                    Event::Token { tok, index } => {
                        assert_eq!(index, toks.len(), "req {}", h.id());
                        toks.push(tok);
                    }
                    Event::Finished(f) => fin = Some(f),
                }
            }
            assert!(first, "req {}", h.id());
            assert_eq!(toks, want.generated, "req {}: stream != batch result", h.id());
            let fin = fin.expect("terminal event");
            assert_eq!(fin.generated, want.generated);
            assert_eq!(fin.finish_reason, FinishReason::Length);
        }
        // TPOT roll-ups made it through the merge
        assert!(!report.merged.tpot_s.is_empty());
        assert_eq!(report.merged.worker_stats.len(), 4);
    }

    #[test]
    fn pool_cancel_frees_capacity_for_queued_request() {
        use std::time::Duration;
        // four capacity-1 workers saturated by never-ending requests, one
        // queued short request: a mid-generation cancel must free a slot
        // (the queued request completes) and return the partial greedy
        // prefix with FinishReason::Cancelled
        let make = || Ok(Box::new(micro_backend()) as Box<dyn InferenceBackend>);
        let n_workers = 4usize;
        let pool = serve_pool(
            make,
            PoolConfig {
                engine: EngineConfig { max_active: 1, greedy_chunking: true },
                n_workers,
                spec: None,
                cache: None,
                ..PoolConfig::default()
            },
        );
        let prompt: Vec<u32> = (0..17).map(|j| ((j * 13 + 5) % 128) as u32).collect();
        // reference greedy trace (same seed => same weights as the workers)
        let reference = {
            let be = micro_backend();
            let mut eng =
                Engine::new(&be, EngineConfig { max_active: 1, greedy_chunking: true });
            eng.submit(Request::new(99, prompt.clone(), 4096, "fp32"));
            eng.run().unwrap();
            eng.finished[0].generated.clone()
        };

        let long = 100_000usize;
        let victims: Vec<SubmitHandle> = (0..n_workers)
            .map(|i| pool.submit(Request::new(i as u64, prompt.clone(), long, "fp32")).unwrap())
            .collect();
        // wait until every worker is demonstrably mid-generation
        for v in &victims {
            let mut toks = 0;
            while toks < 2 {
                match v.next_event_timeout(Duration::from_secs(60)).expect("victim streams")
                {
                    Event::Token { .. } => toks += 1,
                    Event::Finished(f) => panic!("victim finished early: {f:?}"),
                    Event::FirstToken => {}
                }
            }
        }
        // every worker at capacity: the queued request cannot start
        let queued = pool.submit(Request::new(10, prompt.clone(), 4, "fp32")).unwrap();
        assert!(queued.try_event().is_none(), "queued request must wait for capacity");

        // cancel one victim mid-generation -> its slot frees -> the queued
        // request is placed and completes
        victims[0].cancel();
        let vfin = finished_within(&victims[0], 60);
        assert_eq!(vfin.finish_reason, FinishReason::Cancelled);
        assert!(!vfin.generated.is_empty() && vfin.generated.len() < long);
        let n = vfin.generated.len().min(reference.len());
        assert_eq!(vfin.generated[..n], reference[..n], "partial != greedy prefix");

        let qfin = finished_within(&queued, 60);
        assert_eq!(qfin.finish_reason, FinishReason::Length);
        assert_eq!(qfin.generated[..], reference[..4]);

        // wind down the remaining victims
        for v in &victims[1..] {
            v.cancel();
        }
        for v in &victims[1..] {
            assert_eq!(finished_within(v, 60).finish_reason, FinishReason::Cancelled);
        }
        let report = pool.finish().unwrap();
        assert!(report.errors.is_empty(), "{:?}", report.errors);
        // capacity accounting: the queued request got a slot because the
        // cancel freed one, never because a worker overcommitted
        assert_eq!(report.capacity_per_worker, 1);
        for (w, &peak) in report.load_peak.iter().enumerate() {
            assert!(peak <= 1, "worker {w} overcommitted: peak {peak}");
        }
        assert_eq!(report.merged.cancelled_requests, 4);
        assert_eq!(report.merged.requests_completed, 5);
        assert_eq!(report.assignments.iter().sum::<u64>(), 5);
    }

    #[test]
    fn dispatcher_cancels_queued_request_without_a_worker() {
        use std::time::Duration;
        // a request cancelled while still in the dispatcher backlog is
        // resolved by the dispatcher itself: terminal event + aggregate
        // result, no worker ever touches it
        let make = || Ok(Box::new(micro_backend()) as Box<dyn InferenceBackend>);
        let pool = serve_pool(
            make,
            PoolConfig {
                engine: EngineConfig { max_active: 1, greedy_chunking: true },
                n_workers: 1,
                spec: None,
                cache: None,
                ..PoolConfig::default()
            },
        );
        let prompt: Vec<u32> = (0..9).map(|j| ((j * 13 + 5) % 128) as u32).collect();
        let victim = pool.submit(Request::new(0, prompt.clone(), 100_000, "fp32")).unwrap();
        // wait until the victim is streaming, so the next submit must queue
        loop {
            match victim.next_event_timeout(Duration::from_secs(60)) {
                Some(Event::Token { .. }) => break,
                Some(_) => {}
                None => panic!("victim never streamed"),
            }
        }
        let queued = pool.submit(Request::new(1, prompt, 4, "fp32")).unwrap();
        queued.cancel();
        // the dispatcher's bounded wait re-runs the sweep even while the
        // victim keeps generating — the queued cancel must resolve without
        // waiting for any worker traffic
        let qf = finished_within(&queued, 60);
        assert_eq!(qf.finish_reason, FinishReason::Cancelled);
        assert!(qf.generated.is_empty(), "never admitted: no tokens");
        victim.cancel(); // wind down the never-ending request
        assert_eq!(finished_within(&victim, 60).finish_reason, FinishReason::Cancelled);
        let report = pool.finish().unwrap();
        assert!(report.errors.is_empty(), "{:?}", report.errors);
        assert_eq!(report.merged.cancelled_requests, 2);
        assert_eq!(report.merged.requests_completed, 2);
    }

    #[test]
    fn all_workers_dead_finishes_requests_with_worker_died() {
        use std::time::Duration;
        // the factory stalls long enough for the submission to reach the
        // dispatcher and be routed, then fails: with no survivor to
        // re-route to, the request must finish with WorkerDied on both the
        // handle and the aggregate channel instead of vanishing
        let make = || -> Result<Box<dyn InferenceBackend>> {
            std::thread::sleep(Duration::from_millis(200));
            Err(anyhow!("backend construction failed on purpose"))
        };
        let pool = serve_pool(
            make,
            PoolConfig {
                engine: EngineConfig { max_active: 2, greedy_chunking: true },
                n_workers: 1,
                spec: None,
                cache: None,
                ..PoolConfig::default()
            },
        );
        let h = pool.submit(Request::new(0, vec![1, 2, 3], 4, "fp32")).unwrap();
        let f = pool.results.recv().expect("terminal WorkerDied result");
        assert_eq!(f.finish_reason, FinishReason::WorkerDied);
        assert!(f.generated.is_empty());
        let hf = h.wait_finished().expect("terminal event on the handle");
        assert_eq!(hf.finish_reason, FinishReason::WorkerDied);
        let report = pool.finish().unwrap();
        assert!(!report.errors.is_empty(), "worker failure must be recorded");
    }

    #[test]
    fn pool_trace_envelopes_are_balanced_and_hub_totals_match() {
        use crate::util::json::Json;
        // distributed envelope handoff: the dispatcher opens each request
        // span at ingress, the owning worker closes it at retire — across
        // 4 workers every lane must still balance, and the hub's live
        // cells must agree with the merged end-of-run report
        let make = || Ok(Box::new(micro_backend()) as Box<dyn InferenceBackend>);
        let hub = Arc::new(TelemetryHub::new());
        let sink = Arc::new(TraceSink::new(1));
        let pool = serve_pool(
            make,
            PoolConfig {
                engine: EngineConfig { max_active: 4, greedy_chunking: true },
                n_workers: 4,
                hub: Some(Arc::clone(&hub)),
                trace: Some(Arc::clone(&sink)),
                ..PoolConfig::default()
            },
        );
        let reqs = stress_requests();
        let n = reqs.len();
        for r in reqs {
            pool.submit(r).unwrap();
        }
        for _ in 0..n {
            pool.results.recv().expect("pool result");
        }
        let report = pool.finish().unwrap();
        assert!(report.errors.is_empty(), "{:?}", report.errors);

        // scrape view == report view: two reads of the same cells
        assert_eq!(
            hub.total(Counter::RequestsCompleted),
            report.merged.requests_completed
        );
        assert_eq!(
            hub.total(Counter::TokensGenerated),
            report.merged.tokens_generated
        );
        assert_eq!(hub.total(Counter::PromptTokens), report.merged.prompt_tokens);

        let doc = sink.to_chrome_json();
        let events = doc.arr_field("traceEvents").unwrap();
        for id in 0..n as u64 {
            let (mut begins, mut ends) = (0usize, 0usize);
            for e in events {
                if e.usize_field("pid").unwrap() != 0
                    || e.usize_field("tid").unwrap() as u64 != id
                {
                    continue;
                }
                match e.str_field("ph").unwrap() {
                    "B" => begins += 1,
                    "E" => ends += 1,
                    _ => {}
                }
            }
            assert_eq!(
                (begins, ends),
                (1, 1),
                "req {id}: dispatcher-opened envelope must close exactly once"
            );
        }
    }

    #[test]
    fn worker_died_requests_close_their_trace_envelopes() {
        use crate::util::json::Json;
        use std::time::Duration;
        // a request lost to worker death is resolved by the dispatcher —
        // its trace envelope must still close, with the WorkerDied reason
        let make = || -> Result<Box<dyn InferenceBackend>> {
            std::thread::sleep(Duration::from_millis(200));
            Err(anyhow!("backend construction failed on purpose"))
        };
        let sink = Arc::new(TraceSink::new(1));
        let pool = serve_pool(
            make,
            PoolConfig {
                engine: EngineConfig { max_active: 2, greedy_chunking: true },
                n_workers: 1,
                trace: Some(Arc::clone(&sink)),
                ..PoolConfig::default()
            },
        );
        let h = pool.submit(Request::new(0, vec![1, 2, 3], 4, "fp32")).unwrap();
        let f = pool.results.recv().expect("terminal WorkerDied result");
        assert_eq!(f.finish_reason, FinishReason::WorkerDied);
        drop(h);
        let _ = pool.finish().unwrap();

        let doc = sink.to_chrome_json();
        let events = doc.arr_field("traceEvents").unwrap();
        let lane: Vec<&Json> = events
            .iter()
            .filter(|e| {
                e.usize_field("pid").unwrap() == 0 && e.usize_field("tid").unwrap() == 0
            })
            .collect();
        let begins = lane.iter().filter(|e| e.str_field("ph").unwrap() == "B").count();
        let ends: Vec<_> =
            lane.iter().filter(|e| e.str_field("ph").unwrap() == "E").collect();
        assert_eq!(begins, 1, "envelope opened at ingress");
        assert_eq!(ends.len(), 1, "envelope closed by the dispatcher");
        assert_eq!(
            ends[0].get("args").unwrap().str_field("finish_reason").unwrap(),
            "WorkerDied"
        );
    }

    #[test]
    fn overload_dispatcher_sheds_backlog_and_retry_succeeds() {
        use std::time::Duration;
        // one capacity-1 worker held by a never-ending request, backlog
        // bounded at 1: the second queued arrival must shed with a
        // retriable Overloaded terminal, and once the backlog drains a
        // retry completes normally — zero requests lost either way
        let make = || Ok(Box::new(micro_backend()) as Box<dyn InferenceBackend>);
        let pool = serve_pool(
            make,
            PoolConfig {
                engine: EngineConfig { max_active: 1, greedy_chunking: true },
                n_workers: 1,
                sched: SchedPolicy { max_queue: 1, ..SchedPolicy::default() },
                ..PoolConfig::default()
            },
        );
        let prompt: Vec<u32> = (0..9).map(|j| ((j * 13 + 5) % 128) as u32).collect();
        let victim = pool.submit(Request::new(0, prompt.clone(), 100_000, "fp32")).unwrap();
        loop {
            match victim.next_event_timeout(Duration::from_secs(60)) {
                Some(Event::Token { .. }) => break,
                Some(_) => {}
                None => panic!("victim never streamed"),
            }
        }
        // q1 fills the bounded backlog (the worker is at capacity); q2
        // finds it full and sheds.  Ingress messages are ordered and the
        // dispatcher re-runs placement between them, so the outcome is
        // deterministic.
        let q1 = pool.submit(Request::new(1, prompt.clone(), 4, "fp32")).unwrap();
        let q2 = pool.submit(Request::new(2, prompt.clone(), 4, "fp32")).unwrap();
        let shed = finished_within(&q2, 60);
        assert_eq!(shed.finish_reason, FinishReason::Overloaded);
        assert!(shed.generated.is_empty(), "shed before any admission");
        // the freed slot serves the queued request, then a retry of the
        // shed one lands in an empty backlog and completes
        victim.cancel();
        assert_eq!(finished_within(&victim, 60).finish_reason, FinishReason::Cancelled);
        assert_eq!(finished_within(&q1, 60).finish_reason, FinishReason::Length);
        let retry = pool.submit(Request::new(3, prompt, 4, "fp32")).unwrap();
        assert_eq!(finished_within(&retry, 60).finish_reason, FinishReason::Length);
        let report = pool.finish().unwrap();
        assert!(report.errors.is_empty(), "{:?}", report.errors);
        // zero lost: every submit reached exactly one terminal result
        assert_eq!(report.merged.requests_completed, 4);
        assert_eq!(report.merged.requests_shed, 1);
        assert_eq!(report.merged.cancelled_requests, 1);
        // latency purity: only the three worker-retired requests sampled
        assert_eq!(report.merged.latency.count(), 3);
        assert!(report.merged.summary().contains("shed=1"), "{}", report.merged.summary());
    }

    #[test]
    fn dispatcher_drops_never_pollute_latency_histogram() {
        use std::time::Duration;
        // regression for the dispatcher recording `note_latency(total_s)`
        // with `ttft_s: 0.0` for requests it resolves itself: a backlog
        // cancellation must count under requests_dropped and leave the
        // latency histogram to requests that actually completed on a worker
        let make = || Ok(Box::new(micro_backend()) as Box<dyn InferenceBackend>);
        let pool = serve_pool(
            make,
            PoolConfig {
                engine: EngineConfig { max_active: 1, greedy_chunking: true },
                n_workers: 1,
                ..PoolConfig::default()
            },
        );
        let prompt: Vec<u32> = (0..9).map(|j| ((j * 13 + 5) % 128) as u32).collect();
        let victim = pool.submit(Request::new(0, prompt.clone(), 100_000, "fp32")).unwrap();
        loop {
            match victim.next_event_timeout(Duration::from_secs(60)) {
                Some(Event::Token { .. }) => break,
                Some(_) => {}
                None => panic!("victim never streamed"),
            }
        }
        let queued = pool.submit(Request::new(1, prompt, 4, "fp32")).unwrap();
        queued.cancel();
        assert_eq!(finished_within(&queued, 60).finish_reason, FinishReason::Cancelled);
        victim.cancel();
        assert_eq!(finished_within(&victim, 60).finish_reason, FinishReason::Cancelled);
        let report = pool.finish().unwrap();
        assert!(report.errors.is_empty(), "{:?}", report.errors);
        assert_eq!(report.merged.requests_completed, 2);
        assert_eq!(report.merged.cancelled_requests, 2);
        // the dispatcher-resolved cancel is a drop, not a latency sample
        assert_eq!(report.merged.requests_dropped, 1);
        assert_eq!(
            report.merged.latency.count(),
            1,
            "only the worker-retired request may sample latency"
        );
    }

    /// Spin up `n` worker processes (well: in-process listeners with the
    /// exact `serve --worker-mode` loop) on ephemeral ports, each holding
    /// the same same-seed micro backend as the local workers.
    fn start_remote_workers(n: usize) -> Vec<crate::remote::WorkerServer> {
        (0..n)
            .map(|_| {
                crate::remote::serve_worker(
                    "127.0.0.1:0",
                    || Ok(Box::new(micro_backend()) as Box<dyn InferenceBackend>),
                    PoolConfig {
                        engine: EngineConfig { max_active: 4, greedy_chunking: true },
                        n_workers: 1,
                        ..PoolConfig::default()
                    },
                )
                .expect("bind remote worker")
            })
            .collect()
    }

    #[test]
    fn remote_mixed_pool_token_exact_with_all_local_greedy() {
        // 2 local threads + 2 remote processes must produce bit-identical
        // tokens to 4 local threads on the same 64-request greedy trace —
        // the wire changes placement, never results
        let make = || Ok(Box::new(micro_backend()) as Box<dyn InferenceBackend>);
        let n_reqs = stress_requests().len();

        let run = |n_workers: usize, remote: Vec<String>| {
            let pool = serve_pool(
                make,
                PoolConfig {
                    engine: EngineConfig { max_active: 4, greedy_chunking: true },
                    n_workers,
                    remote,
                    ..PoolConfig::default()
                },
            );
            for r in stress_requests() {
                pool.submit(r).unwrap();
            }
            let mut got: Vec<(u64, Vec<u32>)> = (0..n_reqs)
                .map(|_| {
                    let f = pool.results.recv().expect("pool result");
                    (f.id, f.generated)
                })
                .collect();
            let report = pool.finish().unwrap();
            assert!(report.errors.is_empty(), "{:?}", report.errors);
            got.sort();
            (got, report)
        };

        let (want, _) = run(4, Vec::new());
        let servers = start_remote_workers(2);
        let addrs: Vec<String> = servers.iter().map(|s| s.addr().to_string()).collect();
        let (got, report) = run(2, addrs);
        assert_eq!(want, got, "mixing remote workers changed generated tokens");

        // the remotes joined the router's budget with their handshaken
        // capacity and actually took traffic
        assert_eq!(report.capacities, vec![4, 4, 4, 4]);
        assert_eq!(report.assignments.len(), 4);
        assert_eq!(report.assignments.iter().sum::<u64>(), n_reqs as u64);
        assert!(
            report.assignments[2] + report.assignments[3] > 0,
            "remote workers saw no traffic: {:?}",
            report.assignments
        );
        for s in servers {
            s.kill();
            s.wait().unwrap();
        }
    }

    #[test]
    fn remote_mixed_pool_token_exact_with_all_local_sampled() {
        use crate::coordinator::sampler::SamplingParams;
        // seeded sampling is position-keyed, so the sampled stream must
        // also survive the process boundary bit-exactly (the wire carries
        // the full SamplingParams, including the seed)
        let make = || Ok(Box::new(micro_backend()) as Box<dyn InferenceBackend>);
        let sampled_reqs = || -> Vec<Request> {
            (0..12usize)
                .map(|i| {
                    let plen = [3usize, 9, 17, 33][i % 4];
                    let prompt: Vec<u32> =
                        (0..plen).map(|j| ((i * 131 + j * 17) % 128) as u32).collect();
                    Request::new(i as u64, prompt, 6, "fp32").with_sampling(
                        SamplingParams {
                            temperature: 1.0,
                            top_k: 40,
                            seed: 9000 + i as u64,
                            ..SamplingParams::default()
                        },
                    )
                })
                .collect()
        };
        let run = |n_workers: usize, remote: Vec<String>| {
            let pool = serve_pool(
                make,
                PoolConfig {
                    engine: EngineConfig { max_active: 4, greedy_chunking: true },
                    n_workers,
                    remote,
                    ..PoolConfig::default()
                },
            );
            for r in sampled_reqs() {
                pool.submit(r).unwrap();
            }
            let mut got: Vec<(u64, Vec<u32>)> = (0..12)
                .map(|_| {
                    let f = pool.results.recv().expect("pool result");
                    (f.id, f.generated)
                })
                .collect();
            let report = pool.finish().unwrap();
            assert!(report.errors.is_empty(), "{:?}", report.errors);
            got.sort();
            got
        };

        let want = run(4, Vec::new());
        let servers = start_remote_workers(2);
        let addrs: Vec<String> = servers.iter().map(|s| s.addr().to_string()).collect();
        let got = run(2, addrs);
        assert_eq!(want, got, "sampled stream diverged across the wire");
        for s in servers {
            s.kill();
            s.wait().unwrap();
        }
    }

    #[test]
    fn remote_worker_killed_mid_generation_reroutes_zero_lost() {
        use std::time::Duration;
        // a remote worker dies mid-stream (socket severed, no goodbye —
        // what `kill -9` looks like): its in-flight requests must re-route
        // to the survivor and every submit still reach exactly one
        // terminal result
        let make = || Ok(Box::new(micro_backend()) as Box<dyn InferenceBackend>);
        let hub = Arc::new(TelemetryHub::new());
        let servers = start_remote_workers(1);
        let addr = servers[0].addr().to_string();
        let pool = serve_pool(
            make,
            PoolConfig {
                engine: EngineConfig { max_active: 4, greedy_chunking: true },
                n_workers: 1,
                remote: vec![addr.clone()],
                hub: Some(Arc::clone(&hub)),
                ..PoolConfig::default()
            },
        );
        let n = 16usize;
        for i in 0..n {
            let plen = 5 + (i % 7) * 4;
            let prompt: Vec<u32> =
                (0..plen).map(|j| ((i * 131 + j * 17) % 128) as u32).collect();
            pool.submit(Request::new(i as u64, prompt, 48, "fp32")).unwrap();
        }
        // wait until the remote is visibly streaming (its proxy has read
        // event frames) so the kill lands mid-generation, not before
        // routing or after completion
        let transport = hub
            .remotes()
            .into_iter()
            .find(|t| t.addr() == addr)
            .expect("transport registered");
        let t0 = std::time::Instant::now();
        while transport.frames_in() < 3 {
            assert!(t0.elapsed() < Duration::from_secs(60), "remote never streamed");
            std::thread::sleep(Duration::from_millis(2));
        }
        servers.into_iter().next().unwrap().kill();

        // every request completes — re-routed ones restart on the local
        // survivor, already-finished ones are not duplicated
        let mut seen: Vec<u64> = (0..n)
            .map(|_| {
                let f = pool.results.recv().expect("result despite worker death");
                assert_eq!(f.generated.len(), 48, "req {} truncated", f.id);
                f.id
            })
            .collect();
        seen.sort_unstable();
        assert_eq!(seen, (0..n as u64).collect::<Vec<_>>(), "lost or duplicated ids");

        let report = pool.finish().unwrap();
        assert!(
            report.errors.iter().any(|e| e.contains(&format!("worker {}", 1))),
            "death not recorded: {:?}",
            report.errors
        );
        assert!(
            report.errors.iter().any(|e| e.contains("re-routing")),
            "re-route not recorded: {:?}",
            report.errors
        );
        // the transport counted the disconnect and the requeued requests
        assert!(transport.disconnects() >= 1);
        assert!(transport.requeued() >= 1, "kill landed with nothing in flight");
        assert_eq!(report.merged.requests_completed, n as u64);
    }

    #[test]
    fn remote_unreachable_address_joins_dead_without_failing_pool() {
        // nothing listens on this address: the pool must come up, record
        // the connect failure as a worker death, and serve everything on
        // the local worker
        let make = || Ok(Box::new(micro_backend()) as Box<dyn InferenceBackend>);
        let dead_addr = {
            // bind-then-drop yields a port that is almost surely closed
            let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        let pool = serve_pool(
            make,
            PoolConfig {
                engine: EngineConfig { max_active: 4, greedy_chunking: true },
                n_workers: 1,
                remote: vec![dead_addr],
                ..PoolConfig::default()
            },
        );
        for i in 0..4u64 {
            pool.submit(Request::new(i, vec![1, 2, 3], 4, "fp32")).unwrap();
        }
        for _ in 0..4 {
            let f = pool.results.recv().expect("local worker result");
            assert_eq!(f.generated.len(), 4);
        }
        let report = pool.finish().unwrap();
        assert_eq!(report.capacities, vec![4, 0], "dead remote budgets zero");
        assert!(
            report.errors.iter().any(|e| e.contains("remote worker")),
            "connect failure must be recorded: {:?}",
            report.errors
        );
        assert_eq!(report.merged.requests_completed, 4);
    }
}
