//! Request router: spreads incoming requests over worker engines by least
//! outstanding load (state-slot aware — the Mamba serving advantage: a
//! worker's remaining capacity is exactly `capacity - in_use`, no
//! sequence-length estimation needed).
//!
//! The single-host deployment runs one worker; the policy logic is
//! nevertheless real and unit-tested with mock workers, and
//! `serve_threaded` wires an [`Engine`] into a worker thread with mpsc
//! queues for asynchronous submission.

use std::sync::mpsc;
use std::thread;

use anyhow::Result;

use crate::backend::InferenceBackend;

use super::request::{FinishedRequest, Request};
use super::scheduler::{Engine, EngineConfig};

/// Abstract view of a worker the router can place requests on.
pub trait Worker {
    /// currently held state slots
    fn load(&self) -> usize;
    /// total state slots
    fn capacity(&self) -> usize;
}

/// Least-loaded routing with capacity awareness.
#[derive(Debug, Default)]
pub struct Router {
    /// requests routed per worker (for accounting/tests)
    pub assignments: Vec<u64>,
}

impl Router {
    pub fn new(n_workers: usize) -> Self {
        Self { assignments: vec![0; n_workers] }
    }

    /// Pick the worker with the most free slots; `None` if all full.
    pub fn route<W: Worker>(&mut self, workers: &[W]) -> Option<usize> {
        let (mut best, mut best_free) = (None, 0usize);
        for (i, w) in workers.iter().enumerate() {
            let free = w.capacity().saturating_sub(w.load());
            if free > best_free {
                best = Some(i);
                best_free = free;
            }
        }
        if let Some(i) = best {
            self.assignments[i] += 1;
        }
        best
    }
}

/// Run an engine on a worker thread; returns a submission channel and a
/// results channel.  The worker *constructs* its own backend from the
/// factory closure rather than borrowing one (PJRT clients are not Sync —
/// exactly like a real deployment where each worker process owns a
/// device; the same factory shape is what a sharded multi-worker launch
/// will fan out).  Dropping the submitter drains and joins the worker.
pub fn serve_threaded<F>(
    make_backend: F,
    cfg: EngineConfig,
) -> (mpsc::Sender<Request>, mpsc::Receiver<FinishedRequest>, thread::JoinHandle<Result<()>>)
where
    F: FnOnce() -> Result<Box<dyn InferenceBackend>> + Send + 'static,
{
    let (tx_req, rx_req) = mpsc::channel::<Request>();
    let (tx_done, rx_done) = mpsc::channel::<FinishedRequest>();
    let handle = thread::spawn(move || -> Result<()> {
        let be = make_backend()?;
        let mut engine = Engine::new(be.as_ref(), cfg);
        engine.metrics.start();
        loop {
            // drain whatever is queued without blocking; block only if idle
            let mut disconnected = false;
            loop {
                match rx_req.try_recv() {
                    Ok(r) => engine.submit(r),
                    Err(mpsc::TryRecvError::Empty) => break,
                    Err(mpsc::TryRecvError::Disconnected) => {
                        disconnected = true;
                        break;
                    }
                }
            }
            if engine.n_pending() == 0 && engine.n_active() == 0 {
                if disconnected {
                    break;
                }
                match rx_req.recv() {
                    Ok(r) => engine.submit(r),
                    Err(_) => break,
                }
            }
            engine.step()?;
            for f in engine.finished.drain(..) {
                let _ = tx_done.send(f);
            }
        }
        engine.metrics.stop();
        Ok(())
    });
    (tx_req, rx_done, handle)
}

#[cfg(test)]
mod tests {
    use super::*;

    struct MockWorker {
        load: usize,
        cap: usize,
    }

    impl Worker for MockWorker {
        fn load(&self) -> usize {
            self.load
        }
        fn capacity(&self) -> usize {
            self.cap
        }
    }

    #[test]
    fn routes_to_least_loaded() {
        let mut r = Router::new(3);
        let ws = vec![
            MockWorker { load: 5, cap: 8 },
            MockWorker { load: 1, cap: 8 },
            MockWorker { load: 7, cap: 8 },
        ];
        assert_eq!(r.route(&ws), Some(1));
        assert_eq!(r.assignments, vec![0, 1, 0]);
    }

    #[test]
    fn none_when_all_full() {
        let mut r = Router::new(2);
        let ws = vec![
            MockWorker { load: 8, cap: 8 },
            MockWorker { load: 8, cap: 8 },
        ];
        assert_eq!(r.route(&ws), None);
    }

    #[test]
    fn capacity_aware_not_just_load() {
        // worker 0 has lower load but less free capacity
        let mut r = Router::new(2);
        let ws = vec![
            MockWorker { load: 1, cap: 2 },
            MockWorker { load: 3, cap: 16 },
        ];
        assert_eq!(r.route(&ws), Some(1));
    }

    #[test]
    fn serve_threaded_roundtrip_on_native_backend() {
        use crate::backend::NativeBackend;

        let (tx, rx, handle) = serve_threaded(
            || Ok(Box::new(NativeBackend::synthetic(3)) as Box<dyn InferenceBackend>),
            EngineConfig { max_active: 4, greedy_chunking: true },
        );
        let n = 3usize;
        for id in 0..n {
            let prompt: Vec<u32> = (0..24).map(|j| ((id * 97 + j * 13) % 512) as u32).collect();
            tx.send(Request::new(id as u64, prompt, 5, "fp32")).unwrap();
        }
        let mut done = Vec::new();
        for _ in 0..n {
            let f = rx.recv().expect("worker produced a result");
            assert_eq!(f.generated.len(), 5);
            done.push(f.id);
        }
        done.sort_unstable();
        assert_eq!(done, vec![0, 1, 2]);
        drop(tx); // drains and joins the worker
        handle.join().unwrap().unwrap();
    }
}
