//! FastMamba CLI — the Layer-3 leader entrypoint.
//!
//! Subcommands:
//!   serve    — run the serving engine on a synthetic request trace
//!              (--speculate K switches to the draft/verify speculative mode)
//!   report   — regenerate any paper table/figure (--id table2|fig9|...|all)
//!   simulate — accelerator performance model (prefill/decode sweeps)
//!   info     — artifacts + model + accelerator summary

use anyhow::{bail, Result};

use fastmamba::config::{AcceleratorConfig, ModelConfig};
use fastmamba::coordinator::{
    DrafterBackend, Engine, EngineConfig, Request, SpecConfig, SpecEngine,
};
use fastmamba::runtime::Runtime;
use fastmamba::sim::PerfModel;
use fastmamba::util::cli::Args;
use fastmamba::util::rng::Rng;
use fastmamba::{eval, report};

fn main() -> Result<()> {
    let args = Args::from_env();
    match args.subcommand.as_deref() {
        Some("serve") => serve(&args),
        Some("report") => run_report(&args),
        Some("simulate") => simulate(&args),
        Some("info") => info(),
        other => {
            if let Some(o) = other {
                eprintln!("unknown subcommand {o}");
            }
            eprintln!(
                "usage: fastmamba <serve|report|simulate|info> [--flags]\n\
                 \n  serve    --requests N --max-new N --variant fp32|fastmamba --prompt-len N\
                 \n           --speculate K [--draft-backend native|pjrt]\
                 \n  report   --id all|table1|table2|table3|table4|table_spec|fig1|fig3|fig9|fig10\
                 \n  simulate --model mamba2-130m|mamba2-2.7b --seq-len N --batch N\
                 \n  info"
            );
            Ok(())
        }
    }
}

fn serve(args: &Args) -> Result<()> {
    let rt = Runtime::load_default()?;
    let n_requests = args.usize_or("requests", 8);
    let max_new = args.usize_or("max-new", 16);
    let prompt_len = args.usize_or("prompt-len", 48);
    let variant = args.get_or("variant", "fp32");
    let speculate = args.usize_or("speculate", 0);
    let vocab = rt.weights_host.cfg.vocab_size;

    let mut rng = Rng::new(args.usize_or("seed", 7) as u64);
    let corpus = eval::load_corpus(&rt.dir)?;
    let requests: Vec<Request> = (0..n_requests)
        .map(|id| {
            let start = rng.below(corpus.len() - prompt_len - 1);
            let prompt: Vec<u32> = corpus[start..start + prompt_len]
                .iter()
                .map(|t| t % vocab as u32)
                .collect();
            Request::new(id as u64, prompt, max_new, &variant)
        })
        .collect();

    let finished = if speculate > 0 {
        // speculative mode: quantized drafter, `--variant` as the verifier
        let backend = match args.get_or("draft-backend", "native").as_str() {
            "pjrt" => DrafterBackend::Pjrt,
            _ => DrafterBackend::Native,
        };
        let mut engine = SpecEngine::new(
            &rt,
            SpecConfig {
                draft_k: speculate,
                draft_variant: args.get_or("draft-variant", "fastmamba"),
                verify_variant: variant.clone(),
                drafter_backend: backend,
                max_active: 8,
            },
        );
        for r in requests {
            engine.submit(r);
        }
        engine.run()?;
        println!("{}", engine.metrics.summary());
        println!(
            "speculative: k={} rounds={} verify_calls={} rollbacks={} \
             accept_p50={:.1}%",
            speculate,
            engine.metrics.spec_rounds,
            engine.metrics.verify_calls,
            engine.metrics.rollbacks,
            engine.metrics.acceptance_p50() * 100.0
        );
        engine.finished
    } else {
        let mut engine = Engine::new(&rt, EngineConfig::default());
        for r in requests {
            engine.submit(r);
        }
        engine.run()?;
        println!("{}", engine.metrics.summary());
        engine.finished
    };
    for f in finished.iter().take(3) {
        println!(
            "  req {}: {} prompt toks -> {:?}...",
            f.id,
            f.prompt_len,
            &f.generated[..f.generated.len().min(8)]
        );
    }
    Ok(())
}

fn run_report(args: &Args) -> Result<()> {
    match args.get_or("id", "all").as_str() {
        "all" => report::all()?,
        "table1" => report::table1(),
        "table2" => report::table2(
            args.usize_or("ppl-windows", 6),
            args.usize_or("cloze-items", 16),
        )?,
        "table3" => report::table3(),
        "table4" => report::table4(),
        "table_spec" => report::table_spec(),
        "fig1" => report::fig1(),
        "fig3" => report::fig3(),
        "fig9" => report::fig9(None),
        "fig10" => report::fig10(),
        other => bail!("unknown report id {other}"),
    }
    Ok(())
}

fn simulate(args: &Args) -> Result<()> {
    let model = args.get_or("model", "mamba2-130m");
    let Some(cfg) = ModelConfig::by_name(&model) else {
        bail!("unknown model {model}");
    };
    let pm = PerfModel::new(AcceleratorConfig::default(), cfg.clone());
    let seq_len = args.usize_or("seq-len", 512);
    let batch = args.usize_or("batch", 1);
    let p = pm.prefill(seq_len);
    println!(
        "prefill {model} L={seq_len}: {:.3} ms ({} cycles, bottleneck={}) {:.0} tok/s",
        p.seconds * 1e3,
        p.cycles,
        p.bottleneck,
        p.tokens_per_s
    );
    for (name, frac) in p.breakdown.fractions() {
        println!("  {name:<10} {:.1}%", frac * 100.0);
    }
    let d = pm.decode(batch);
    println!(
        "decode {model} B={batch}: {:.3} ms/step, {:.2} tok/s ({})",
        d.seconds_per_step * 1e3,
        d.tokens_per_s,
        if d.compute_bound { "compute-bound" } else { "DRAM-bound" }
    );
    Ok(())
}

fn info() -> Result<()> {
    let dir = fastmamba::model::weights::artifacts_dir();
    println!("artifacts dir: {}", dir.display());
    let rt = Runtime::load_default()?;
    let cfg = &rt.weights_host.cfg;
    println!(
        "serve model: {} (d_model={} layers={} heads={} vocab={})",
        cfg.name, cfg.d_model, cfg.n_layer, cfg.nheads(), cfg.vocab_size
    );
    println!(
        "artifacts: {} graphs; prefill buckets {:?}; decode batches {:?}",
        rt.manifest.artifacts.len(),
        rt.prefill_buckets(),
        rt.decode_batches()
    );
    let acc = AcceleratorConfig::default();
    println!(
        "accelerator: {} MHz, {} linear MAC/cyc, {} conv MAC/cyc, {} ssm ops/cyc",
        acc.clock_hz / 1_000_000,
        acc.linear_macs_per_cycle(),
        acc.conv_macs_per_cycle(),
        acc.ssm_ops_per_cycle()
    );
    Ok(())
}
