//! FastMamba CLI — the Layer-3 leader entrypoint.
//!
//! Subcommands:
//!   serve    — run the serving engine on a synthetic request trace
//!              (--backend pjrt|native|auto picks the execution backend;
//!              --speculate K switches to the draft/verify speculative mode)
//!   report   — regenerate any paper table/figure (--id table2|fig9|...|all)
//!   simulate — accelerator performance model (prefill/decode sweeps)
//!   info     — backend + artifacts + model + accelerator summary
//!
//! Every subcommand works with no `artifacts/manifest.json` and no
//! xla_extension: `--backend auto` (the default) falls back to the
//! artifact-free native backend.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, Result};

use fastmamba::backend::{self, BackendKind, InferenceBackend, NativeBackend};
use fastmamba::config::{AcceleratorConfig, ModelConfig};
use fastmamba::coordinator::{
    serve_pool, Engine, EngineConfig, Event, FinishReason, PoolConfig, Request, SchedPolicy,
    SpecConfig, SpecEngine, SubmitHandle,
};
use fastmamba::obs::{
    serve_metrics, SloConfig, SloMonitor, StallWatchdog, TelemetryHub, TraceSink,
};
use fastmamba::statecache::{CacheConfig, StateCache};
use fastmamba::model::weights::{artifacts_dir, Manifest};
use fastmamba::sim::PerfModel;
use fastmamba::util::cli::Args;
use fastmamba::util::json;
use fastmamba::util::rng::Rng;
use fastmamba::{eval, report};

fn main() -> Result<()> {
    let args = Args::from_env();
    match args.subcommand.as_deref() {
        Some("serve") => serve(&args),
        Some("report") => run_report(&args),
        Some("simulate") => simulate(&args),
        Some("info") => info(),
        other => {
            if let Some(o) = other {
                eprintln!("unknown subcommand {o}");
            }
            eprintln!(
                "usage: fastmamba <serve|report|simulate|info> [--flags]\n\
                 \n  serve    --requests N --max-new N --variant fp32|fastmamba --prompt-len N\
                 \n           --backend auto|pjrt|native --max-active N --workers N\
                 \n           --speculate K [--draft-backend native|pjrt]\
                 \n           --state-cache-mb N (0 = off; shared SSM prefix/session cache)\
                 \n           --state-cache-dir PATH (disk spill tier under the state cache;\
                 \n                                   implies the cache on — snapshots survive\
                 \n                                   restarts and warm-start session resume)\
                 \n           --worker-mode HOST:PORT (run as a remote worker process: serve\
                 \n                                    engine work to a dispatcher over the\
                 \n                                    wire protocol until killed)\
                 \n           --remote-worker HOST:PORT[,HOST:PORT...] (adopt remote worker\
                 \n                                    processes into the serving pool\
                 \n                                    alongside the local --workers threads)\
                 \n           --stream (print tokens as they are produced)\
                 \n           --deadline-ms N (per-request completion deadline)\
                 \n           --max-queue N (bound the pending queue; excess submissions are\
                 \n                          shed with Overloaded / HTTP 429; 0 = unbounded)\
                 \n           --age-rate R (priority levels gained per second of queue wait;\
                 \n                         0 = strict static priority)\
                 \n           --preempt-threshold P (arrivals at effective priority >= P evict\
                 \n                                  the lowest-priority running request; needs\
                 \n                                  --state-cache-mb > 0 for exact resume)\
                 \n           --http-addr HOST:PORT (OpenAI-style /v1/completions + SSE frontend;\
                 \n                                  port 0 picks a free port, printed on startup)\
                 \n           --http-requests N (serve N completions then exit; 0 = run until killed)\
                 \n           --metrics-addr HOST:PORT (live introspection listener: Prometheus\
                 \n                                     /metrics plus /statusz /readyz\
                 \n                                     /debug/config /debug/flight)\
                 \n           --metrics-json PATH (write the final metrics snapshot as JSON)\
                 \n           --slo-ttft-ms N (time-to-first-token SLO; burn-rate gauges and\
                 \n                            violation counters on /metrics)\
                 \n           --slo-tpot-ms N (per-token latency SLO)\
                 \n           --slo-availability F (availability SLO in (0,1), e.g. 0.999;\
                 \n                                 shed + dropped requests burn the budget)\
                 \n           --stall-ms N (stall watchdog: flag requests with no token\
                 \n                         progress and a dispatcher with no dispatch\
                 \n                         progress for N ms; dumps the flight recorder)\
                 \n           --trace-out PATH (Chrome trace_event JSON of request spans)\
                 \n           --trace-sample N (trace every Nth request; default 1 = all)\
                 \n           --log-every-s N (periodic one-line status log to stdout)\
                 \n  report   --id all|table1|table2|table3|table4|table_spec|fig1|fig3|fig9|fig10\
                 \n  simulate --model mamba2-130m|mamba2-2.7b --seq-len N --batch N\
                 \n  info"
            );
            Ok(())
        }
    }
}

fn print_event(id: u64, ev: &Event) {
    match ev {
        Event::FirstToken => println!("[stream] req {id}: first token"),
        Event::Token { tok, index } => println!("[stream] req {id}: #{index} -> {tok}"),
        Event::Finished(f) => println!(
            "[stream] req {id}: finished ({:?}, {} tokens, {:.1} ms)",
            f.finish_reason,
            f.generated.len(),
            f.total_s * 1e3
        ),
    }
}

/// Drain and print whatever each handle has buffered.
fn drain_handles(handles: &[SubmitHandle]) {
    for h in handles {
        while let Some(ev) = h.try_event() {
            print_event(h.id(), &ev);
        }
    }
}

fn backend_kind(args: &Args) -> Result<BackendKind> {
    let name = args.get_or("backend", "auto");
    let Some(kind) = BackendKind::from_name(&name) else {
        bail!("unknown backend {name} (expected auto|pjrt|native)");
    };
    Ok(kind)
}

/// Overload-safe scheduling knobs shared by every serve path (see README
/// "Production scheduling"): `--max-queue` bounds admission, `--age-rate`
/// ages queued priorities, `--preempt-threshold` arms preemption.
fn sched_policy(args: &Args) -> Result<SchedPolicy> {
    let mut policy = SchedPolicy {
        age_rate: args.f64_or("age-rate", 0.0),
        max_queue: args.usize_or("max-queue", 0),
        ..SchedPolicy::default()
    };
    if let Some(raw) = args.get("preempt-threshold") {
        let Ok(t) = raw.parse::<i32>() else {
            bail!("--preempt-threshold must be an integer priority, got {raw:?}");
        };
        policy.preempt_threshold = Some(t);
        if args.usize_or("state-cache-mb", 0) == 0 && args.get("state-cache-dir").is_none() {
            eprintln!(
                "note: --preempt-threshold has no effect without --state-cache-mb > 0 \
                 (preempted state snapshots live in the state cache)"
            );
        }
    }
    Ok(policy)
}

/// SLO objectives from the `--slo-*` flags (0 / absent = objective off).
fn slo_config(args: &Args) -> SloConfig {
    let ms = |flag: &str| {
        let v = args.usize_or(flag, 0);
        (v > 0).then(|| v as f64 / 1e3)
    };
    let avail = args.f64_or("slo-availability", 0.0);
    SloConfig {
        ttft_s: ms("slo-ttft-ms"),
        tpot_s: ms("slo-tpot-ms"),
        availability: (avail > 0.0 && avail < 1.0).then_some(avail),
        ..SloConfig::default()
    }
}

/// The resolved serving configuration, as served by `/debug/config`: the
/// effective values after every default/override, not the raw flags.
#[allow(clippy::too_many_arguments)]
fn resolved_config(
    topology: &str,
    workers: usize,
    remotes: usize,
    max_active: usize,
    speculate: usize,
    variant: &str,
    cache_mb: usize,
    sched: &SchedPolicy,
    slo: &SloConfig,
    stall_ms: usize,
) -> json::Json {
    use json::{num, obj, s, Json};
    obj(vec![
        ("topology", s(topology)),
        ("workers", num(workers as f64)),
        ("remote_workers", num(remotes as f64)),
        ("max_active", num(max_active as f64)),
        ("speculate", num(speculate as f64)),
        ("variant", s(variant)),
        ("state_cache_mb", num(cache_mb as f64)),
        (
            "sched",
            obj(vec![
                ("age_rate", num(sched.age_rate)),
                (
                    "preempt_threshold",
                    sched
                        .preempt_threshold
                        .map(|t| num(t as f64))
                        .unwrap_or(Json::Null),
                ),
                ("max_queue", num(sched.max_queue as f64)),
            ]),
        ),
        ("slo", slo.to_json()),
        ("stall_ms", num(stall_ms as f64)),
    ])
}

/// Which of the four serving topologies the flags select (remote workers
/// force the pool topology: they join the local threads behind the same
/// router).
fn topology_name(workers: usize, remotes: usize, speculate: usize) -> &'static str {
    match (workers > 1 || remotes > 0, speculate > 0) {
        (true, true) => "pool-spec",
        (true, false) => "pool-plain",
        (false, true) => "single-spec",
        (false, false) => "single-plain",
    }
}

/// `--remote-worker HOST:PORT[,HOST:PORT...]` — remote worker processes
/// to adopt into the serving pool.
fn remote_workers(args: &Args) -> Vec<String> {
    args.get("remote-worker")
        .map(|v| {
            v.split(',')
                .map(|s| s.trim().to_string())
                .filter(|s| !s.is_empty())
                .collect()
        })
        .unwrap_or_default()
}

/// Resolve `--state-cache-mb` / `--state-cache-dir` into the shared cache.
/// A disk dir implies the cache is on (64 MiB of RAM tier when no size is
/// given) — the dir is the durable tier snapshots spill to and warm-start
/// from across process restarts.
fn state_cache(args: &Args) -> Result<(usize, Option<Arc<StateCache>>)> {
    let dir = args.get("state-cache-dir");
    let mut cache_mb = args.usize_or("state-cache-mb", 0);
    if cache_mb == 0 && dir.is_some() {
        cache_mb = 64;
    }
    if cache_mb == 0 {
        return Ok((0, None));
    }
    let mut cache = StateCache::new(CacheConfig::with_mb(cache_mb));
    if let Some(d) = dir {
        cache = cache.with_disk(fastmamba::statecache::DiskTier::open(d)?);
        println!("state cache disk tier: {d}");
    }
    Ok((cache_mb, Some(Arc::new(cache))))
}

fn serve(args: &Args) -> Result<()> {
    // --worker-mode turns this process into a remote pool worker: no
    // local trace, no HTTP — it serves a dispatcher over the wire protocol
    if let Some(addr) = args.get("worker-mode") {
        let addr = addr.to_string();
        return serve_worker_mode(args, &addr);
    }
    // --http-addr switches from the synthetic trace to the HTTP frontend
    // (requests come from the network instead of the corpus sampler)
    if args.get("http-addr").is_some() {
        return serve_over_http(args);
    }
    let kind = backend_kind(args)?;
    let be = backend::load(kind)?;
    let n_requests = args.usize_or("requests", 8);
    let max_new = args.usize_or("max-new", 16);
    let prompt_len = args.usize_or("prompt-len", 48);
    let variant = args.get_or("variant", "fp32");
    let speculate = args.usize_or("speculate", 0);
    let workers = args.usize_or("workers", 1);
    let remote = remote_workers(args);
    // both engine paths honor --max-active (speculative requests hold two
    // state slots each, hence the lower default)
    let max_active = args.usize_or("max-active", if speculate > 0 { 8 } else { 64 });
    // shared SSM state cache (prefix reuse + session resume, optionally
    // disk-tiered); one Arc is threaded through whichever serving path
    // runs, including every pool worker
    let (cache_mb, cache) = state_cache(args)?;
    // streaming lifecycle flags: --stream prints tokens as each engine
    // step produces them; --deadline-ms bounds per-request latency
    // (expired requests finish with FinishReason::Deadline and partial
    // output).  Both work on all four serve paths (plain/speculative x
    // single-engine/pool).
    let stream = args.bool("stream");
    let deadline_ms = args.usize_or("deadline-ms", 0);
    // overload-safe scheduling: admission bound, priority aging, preemption
    let sched = sched_policy(args)?;
    // observability (see README "Observability"): a telemetry hub backs
    // both the live /metrics endpoint and the periodic status line; the
    // trace sink records per-request spans for --trace-out
    let metrics_addr = args.get("metrics-addr");
    let metrics_json = args.get("metrics-json");
    let trace_out = args.get("trace-out");
    let trace_sample = args.usize_or("trace-sample", 1).max(1);
    let log_every_s = args.usize_or("log-every-s", 0);
    // SLO objectives (--slo-*) and the stall watchdog (--stall-ms) both
    // live on the telemetry hub, so either one forces it into existence
    // even without a /metrics listener
    let slo = slo_config(args);
    let stall_ms = args.usize_or("stall-ms", 0);
    let hub: Option<Arc<TelemetryHub>> = (metrics_addr.is_some()
        || log_every_s > 0
        || slo.is_enabled()
        || stall_ms > 0)
        .then(|| Arc::new(TelemetryHub::new()));
    let trace_sink: Option<Arc<TraceSink>> =
        trace_out.is_some().then(|| Arc::new(TraceSink::new(trace_sample as u64)));
    let mut metrics_server = match (&hub, metrics_addr) {
        (Some(h), Some(addr)) => {
            let srv = serve_metrics(addr, Arc::clone(h))?;
            println!("metrics: http://{}/metrics", srv.addr());
            Some(srv)
        }
        _ => None,
    };
    if let Some(h) = &hub {
        if let Some(c) = &cache {
            h.attach_cache(Arc::clone(c));
        }
        if slo.is_enabled() {
            h.attach_slo(Arc::new(SloMonitor::new(slo.clone())));
        }
        if stall_ms > 0 {
            h.attach_watchdog(Arc::new(StallWatchdog::new(Duration::from_millis(
                stall_ms as u64,
            ))));
        }
        h.attach_config(resolved_config(
            topology_name(workers, remote.len(), speculate),
            workers,
            remote.len(),
            max_active,
            speculate,
            &variant,
            cache_mb,
            &sched,
            &slo,
            stall_ms,
        ));
    }
    let ticker_stop = Arc::new(AtomicBool::new(false));
    let watchdog = hub.as_ref().and_then(|h| h.watchdog());
    let ticker = (log_every_s > 0 || watchdog.is_some()).then(|| {
        let h = Arc::clone(hub.as_ref().expect("hub exists when the obs ticker runs"));
        let stop = Arc::clone(&ticker_stop);
        let watchdog = watchdog.clone();
        std::thread::spawn(move || {
            let period = Duration::from_secs(log_every_s as u64);
            let mut slept = Duration::ZERO;
            while !stop.load(Ordering::Relaxed) {
                std::thread::sleep(Duration::from_millis(100));
                slept += Duration::from_millis(100);
                // the watchdog rides the 100 ms tick so a wedged request
                // is flagged within ~threshold + 100 ms, not +period
                if let Some(wd) = &watchdog {
                    wd.check(&h);
                }
                if log_every_s > 0 && slept >= period {
                    slept = Duration::ZERO;
                    println!("[obs] {}", h.one_line());
                }
            }
        })
    });
    let vocab = be.cfg().vocab_size;

    let mut rng = Rng::new(args.usize_or("seed", 7) as u64);
    let corpus = eval::corpus_for(be.as_ref());
    let requests: Vec<Request> = (0..n_requests)
        .map(|id| {
            let start = rng.below(corpus.len() - prompt_len - 1);
            let prompt: Vec<u32> = corpus[start..start + prompt_len]
                .iter()
                .map(|t| t % vocab as u32)
                .collect();
            let mut r = Request::new(id as u64, prompt, max_new, &variant);
            if deadline_ms > 0 {
                r = r.with_deadline(Duration::from_millis(deadline_ms as u64));
            }
            r
        })
        .collect();

    println!(
        "backend: {} ({}; prefill buckets {:?}, decode batches {:?})",
        be.name(),
        be.cfg().name,
        be.prefill_buckets(),
        be.decode_batches()
    );
    let (finished, final_metrics) = if workers > 1 || !remote.is_empty() {
        // multi-worker pool: every worker builds its own backend from the
        // factory and runs its own engine behind the capacity-aware router
        // (speculative workers draft and verify on their own backend, so
        // --draft-backend does not apply here); remote worker processes
        // join the same router behind wire-protocol proxies
        if speculate > 0 && args.get("draft-backend").is_some() {
            eprintln!(
                "note: --draft-backend is ignored with --workers > 1 \
                 (each worker drafts on its own backend)"
            );
        }
        if !remote.is_empty() {
            println!("remote workers: {}", remote.join(", "));
        }
        drop(be); // workers own their backends; the probe served request gen
        let pool = serve_pool(
            move || backend::load(kind),
            PoolConfig {
                engine: EngineConfig { max_active, greedy_chunking: true },
                n_workers: workers,
                spec: (speculate > 0).then(|| SpecConfig {
                    draft_k: speculate,
                    draft_variant: args.get_or("draft-variant", "fastmamba"),
                    verify_variant: variant.clone(),
                    max_active,
                    reseed_drafter: true,
                }),
                cache: cache.clone(),
                hub: hub.clone(),
                trace: trace_sink.clone(),
                sched: sched.clone(),
                remote: remote.clone(),
            },
        );
        let mut handles = Vec::with_capacity(n_requests);
        for r in requests {
            handles.push(pool.submit(r)?);
        }
        if !stream {
            handles.clear(); // unread events would only buffer
        }
        let mut finished = Vec::with_capacity(n_requests);
        if stream {
            // poll the per-request event streams (printing tokens live)
            // alongside the aggregate results channel
            let mut open = true;
            while open && finished.len() < n_requests {
                let mut progressed = false;
                for h in &handles {
                    while let Some(ev) = h.try_event() {
                        progressed = true;
                        print_event(h.id(), &ev);
                    }
                }
                loop {
                    use std::sync::mpsc::TryRecvError;
                    match pool.results.try_recv() {
                        Ok(f) => {
                            finished.push(f);
                            progressed = true;
                        }
                        Err(TryRecvError::Empty) => break,
                        // pool collapsed (all workers dead): stop reading
                        // so finish() can surface the failure causes
                        Err(TryRecvError::Disconnected) => {
                            open = false;
                            break;
                        }
                    }
                }
                if !progressed {
                    std::thread::sleep(Duration::from_millis(1));
                }
            }
            drain_handles(&handles);
        } else {
            for _ in 0..n_requests {
                match pool.results.recv() {
                    Ok(f) => finished.push(f),
                    // pool collapsed (all workers dead): stop reading so
                    // finish() can surface the per-worker failure causes
                    Err(_) => break,
                }
            }
        }
        let report = pool.finish()?;
        for e in &report.errors {
            eprintln!("worker error: {e}");
        }
        println!("{}", report.merged.summary());
        println!(
            "pool: workers={}+{} remote, assignments={:?} load_peak={:?} capacities={:?}",
            workers, remote.len(), report.assignments, report.load_peak, report.capacities
        );
        let died = finished
            .iter()
            .filter(|f| f.finish_reason == FinishReason::WorkerDied)
            .count();
        if finished.len() < n_requests || died > 0 {
            bail!(
                "pool completed {}/{} requests ({} worker-died; worker errors above)",
                finished.len() - died,
                n_requests,
                died
            );
        }
        (finished, report.merged)
    } else if speculate > 0 {
        // speculative mode: quantized drafter, `--variant` as the verifier.
        // The drafter is its own backend ("native": in-process golden
        // model; "pjrt": the AOT decode executable — shared with the
        // serving backend when that already is PJRT).
        let drafter_box: Option<Box<dyn InferenceBackend>> =
            match args.get_or("draft-backend", "native").as_str() {
                "pjrt" if be.name() == "pjrt" => None, // share the device
                "pjrt" => Some(backend::load(BackendKind::Pjrt)?),
                "native" if be.name() == "native" => None, // already in-process
                "native" => Some(Box::new(NativeBackend::load_default()?)),
                other => bail!("unknown draft backend {other} (expected native|pjrt)"),
            };
        let drafter: &dyn InferenceBackend =
            drafter_box.as_deref().unwrap_or(be.as_ref());
        let mut engine = SpecEngine::with_drafter(
            drafter,
            be.as_ref(),
            SpecConfig {
                draft_k: speculate,
                draft_variant: args.get_or("draft-variant", "fastmamba"),
                verify_variant: variant.clone(),
                max_active,
                reseed_drafter: true,
            },
        )
        .with_policy(sched.clone());
        if let Some(c) = &cache {
            engine = engine.with_cache(Arc::clone(c));
        }
        if let Some(h) = &hub {
            engine = engine
                .with_telemetry(h.register("0"))
                .with_flight(Arc::clone(h.flight()), 0);
        }
        if let Some(s) = &trace_sink {
            engine = engine.with_trace(Arc::clone(s), 0);
        }
        let mut handles = Vec::with_capacity(n_requests);
        for r in requests {
            handles.push(engine.submit(r));
        }
        if !stream {
            handles.clear(); // unread events would only buffer
        }
        if stream {
            // manual drive: drain and print each request's events after
            // every engine step (spec streams verifier-committed runs)
            engine.metrics.start();
            while engine.n_pending() > 0 || engine.n_active() > 0 {
                engine.step()?;
                drain_handles(&handles);
            }
            engine.metrics.stop();
        } else {
            engine.run()?;
        }
        println!("{}", engine.metrics.summary());
        println!(
            "speculative: k={} drafter={} rounds={} verify_calls={} rollbacks={} \
             accept_p50={:.1}%",
            speculate,
            drafter.name(),
            engine.metrics.spec_rounds,
            engine.metrics.verify_calls,
            engine.metrics.rollbacks,
            engine.metrics.acceptance_p50() * 100.0
        );
        (engine.finished, engine.metrics)
    } else {
        let mut engine =
            Engine::new(be.as_ref(), EngineConfig { max_active, greedy_chunking: true })
                .with_policy(sched.clone());
        if let Some(c) = &cache {
            engine = engine.with_cache(Arc::clone(c));
        }
        if let Some(h) = &hub {
            engine = engine
                .with_telemetry(h.register("0"))
                .with_flight(Arc::clone(h.flight()), 0);
        }
        if let Some(s) = &trace_sink {
            engine = engine.with_trace(Arc::clone(s), 0);
        }
        let mut handles = Vec::with_capacity(n_requests);
        for r in requests {
            handles.push(engine.submit(r));
        }
        if !stream {
            handles.clear(); // unread events would only buffer
        }
        if stream {
            engine.metrics.start();
            while engine.n_pending() > 0 || engine.n_active() > 0 {
                engine.step()?;
                drain_handles(&handles);
            }
            engine.metrics.stop();
        } else {
            engine.run()?;
        }
        println!("{}", engine.metrics.summary());
        (engine.finished, engine.metrics)
    };
    if let Some(c) = &cache {
        println!("state cache ({cache_mb} MiB): {}", c.stats().summary());
    }
    print_finish_reasons(&finished);
    for f in finished.iter().take(3) {
        println!(
            "  req {}: {} prompt toks -> {:?}...",
            f.id,
            f.prompt_len,
            &f.generated[..f.generated.len().min(8)]
        );
    }
    // observability teardown: stop the live endpoints, then export the
    // final artifacts (the JSON snapshot and the trace share the exact
    // metrics the summary above printed)
    ticker_stop.store(true, Ordering::Relaxed);
    if let Some(t) = ticker {
        let _ = t.join();
    }
    if let Some(srv) = metrics_server.as_mut() {
        srv.shutdown();
    }
    if let (Some(sink), Some(path)) = (&trace_sink, trace_out) {
        sink.write(path)?;
        println!(
            "trace: {} events -> {path} ({} dropped)",
            sink.len(),
            sink.dropped()
        );
    }
    if let Some(path) = metrics_json {
        std::fs::write(path, json::to_string(&final_metrics.to_json()))?;
        println!("metrics json -> {path}");
    }
    Ok(())
}

/// Finish-reason accounting (Length/StopToken/StopSequence are the normal
/// outcomes; Cancelled/Deadline show the streaming lifecycle at work).
fn print_finish_reasons(finished: &[fastmamba::coordinator::FinishedRequest]) {
    let count = |r: FinishReason| finished.iter().filter(|f| f.finish_reason == r).count();
    println!(
        "finish_reasons: length={} stop={} stop_sequence={} cancelled={} deadline={} \
         worker_died={} overloaded={}",
        count(FinishReason::Length),
        count(FinishReason::StopToken),
        count(FinishReason::StopSequence),
        count(FinishReason::Cancelled),
        count(FinishReason::Deadline),
        count(FinishReason::WorkerDied),
        count(FinishReason::Overloaded),
    );
}

/// `serve --worker-mode HOST:PORT`: run this process as a remote pool
/// worker.  Builds the backend once, binds the wire-protocol listener,
/// and serves dispatcher connections until the process is killed — a
/// dispatcher started with `--remote-worker HOST:PORT` adopts it into its
/// pool next to the local worker threads.  `--max-active`, `--speculate`,
/// the scheduling flags, and the state-cache flags configure the worker's
/// engine exactly as they would a local worker's.
fn serve_worker_mode(args: &Args, addr: &str) -> Result<()> {
    let kind = backend_kind(args)?;
    let variant = args.get_or("variant", "fp32");
    let speculate = args.usize_or("speculate", 0);
    let max_active = args.usize_or("max-active", if speculate > 0 { 8 } else { 64 });
    let (cache_mb, cache) = state_cache(args)?;
    let sched = sched_policy(args)?;
    let cfg = PoolConfig {
        engine: EngineConfig { max_active, greedy_chunking: true },
        n_workers: 1,
        spec: (speculate > 0).then(|| SpecConfig {
            draft_k: speculate,
            draft_variant: args.get_or("draft-variant", "fastmamba"),
            verify_variant: variant.clone(),
            max_active,
            reseed_drafter: true,
        }),
        cache,
        sched,
        ..PoolConfig::default()
    };
    let capacity = cfg.capacity_per_worker();
    let server = fastmamba::remote::serve_worker(addr, move || backend::load(kind), cfg)?;
    // parse-friendly: a supervising script scrapes the bound address off
    // this line (port 0 resolves to an OS-picked port)
    println!("worker: listening on {}", server.addr());
    println!(
        "worker: variant={variant} capacity={capacity} speculate={speculate} \
         state_cache_mb={cache_mb} (serving until killed)"
    );
    server.wait()
}

/// `serve --http-addr`: the OpenAI-style HTTP/SSE frontend over whichever
/// serving topology the other flags select (single/pool x
/// plain/speculative).  Requests arrive over the network as
/// `POST /v1/completions` bodies instead of the synthetic trace; sampling
/// parameters, session ids, deadlines, and priorities ride in on each
/// body.  Telemetry, the state cache, and span traces thread through
/// exactly as in trace-driven serving.
fn serve_over_http(args: &Args) -> Result<()> {
    use fastmamba::server::{serve_http, ApiConfig, ChannelSubmitter, HttpConfig};
    use std::sync::mpsc;

    let kind = backend_kind(args)?;
    let http_addr = args.get("http-addr").expect("caller checked --http-addr");
    let http_requests = args.usize_or("http-requests", 0);
    let variant = args.get_or("variant", "fp32");
    let speculate = args.usize_or("speculate", 0);
    let workers = args.usize_or("workers", 1);
    let remote = remote_workers(args);
    let max_active = args.usize_or("max-active", if speculate > 0 { 8 } else { 64 });
    let (cache_mb, cache) = state_cache(args)?;
    let sched = sched_policy(args)?;
    let metrics_addr = args.get("metrics-addr");
    let metrics_json = args.get("metrics-json");
    let trace_out = args.get("trace-out");
    let trace_sample = args.usize_or("trace-sample", 1).max(1);
    let slo = slo_config(args);
    let stall_ms = args.usize_or("stall-ms", 0);
    let hub: Option<Arc<TelemetryHub>> = (metrics_addr.is_some()
        || slo.is_enabled()
        || stall_ms > 0)
        .then(|| Arc::new(TelemetryHub::new()));
    let trace_sink: Option<Arc<TraceSink>> =
        trace_out.is_some().then(|| Arc::new(TraceSink::new(trace_sample as u64)));
    let mut metrics_server = match (&hub, metrics_addr) {
        (Some(h), Some(addr)) => {
            let srv = serve_metrics(addr, Arc::clone(h))?;
            println!("metrics: http://{}/metrics", srv.addr());
            Some(srv)
        }
        _ => None,
    };
    if let Some(h) = &hub {
        if let Some(c) = &cache {
            h.attach_cache(Arc::clone(c));
        }
        if slo.is_enabled() {
            h.attach_slo(Arc::new(SloMonitor::new(slo.clone())));
        }
        if stall_ms > 0 {
            h.attach_watchdog(Arc::new(StallWatchdog::new(Duration::from_millis(
                stall_ms as u64,
            ))));
        }
        h.attach_config(resolved_config(
            topology_name(workers, remote.len(), speculate),
            workers,
            remote.len(),
            max_active,
            speculate,
            &variant,
            cache_mb,
            &sched,
            &slo,
            stall_ms,
        ));
    }
    let ticker_stop = Arc::new(AtomicBool::new(false));
    let watchdog = hub.as_ref().and_then(|h| h.watchdog());
    let ticker = watchdog.clone().map(|wd| {
        let h = Arc::clone(hub.as_ref().expect("hub exists when --stall-ms is set"));
        let stop = Arc::clone(&ticker_stop);
        std::thread::spawn(move || {
            while !stop.load(Ordering::Relaxed) {
                std::thread::sleep(Duration::from_millis(100));
                wd.check(&h);
            }
        })
    });

    // probe the backend once for the API surface (vocab + served variants)
    let be = backend::load(kind)?;
    let mut http_cfg = HttpConfig::new(ApiConfig {
        variant: variant.clone(),
        variants: be.variants(),
        vocab_size: be.cfg().vocab_size,
        default_max_tokens: args.usize_or("max-new", 16),
    });
    // the frontend's /healthz consults pool liveness through the hub
    if let Some(h) = &hub {
        http_cfg = http_cfg.with_hub(Arc::clone(h));
    }
    println!(
        "backend: {} ({}; prefill buckets {:?}, decode batches {:?})",
        be.name(),
        be.cfg().name,
        be.prefill_buckets(),
        be.decode_batches()
    );

    let (finished, final_metrics) = if workers > 1 || !remote.is_empty() {
        // worker pool: the frontend submits straight into the pool ingress;
        // workers emit events in real time from their own threads (remote
        // worker processes join behind wire-protocol proxies)
        if speculate > 0 && args.get("draft-backend").is_some() {
            eprintln!(
                "note: --draft-backend is ignored with --workers > 1 \
                 (each worker drafts on its own backend)"
            );
        }
        if !remote.is_empty() {
            println!("remote workers: {}", remote.join(", "));
        }
        drop(be);
        let pool = serve_pool(
            move || backend::load(kind),
            PoolConfig {
                engine: EngineConfig { max_active, greedy_chunking: true },
                n_workers: workers,
                spec: (speculate > 0).then(|| SpecConfig {
                    draft_k: speculate,
                    draft_variant: args.get_or("draft-variant", "fastmamba"),
                    verify_variant: variant.clone(),
                    max_active,
                    reseed_drafter: true,
                }),
                cache: cache.clone(),
                hub: hub.clone(),
                trace: trace_sink.clone(),
                sched: sched.clone(),
                remote: remote.clone(),
            },
        );
        let submitter = Arc::new(ChannelSubmitter::new(pool.sender()));
        let mut server = serve_http(http_addr, submitter, http_cfg)?;
        println!("http: listening on {}", server.addr());
        let mut finished = Vec::new();
        loop {
            match pool.results.recv_timeout(Duration::from_millis(200)) {
                Ok(f) => {
                    finished.push(f);
                    if http_requests > 0 && finished.len() >= http_requests {
                        break;
                    }
                }
                Err(mpsc::RecvTimeoutError::Timeout) => {}
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }
        server.shutdown();
        let report = pool.finish()?;
        for e in &report.errors {
            eprintln!("worker error: {e}");
        }
        println!("{}", report.merged.summary());
        println!(
            "pool: workers={}+{} remote, assignments={:?} load_peak={:?} capacities={:?}",
            workers, remote.len(), report.assignments, report.load_peak, report.capacities
        );
        (finished, report.merged)
    } else {
        // single engine: the frontend feeds a channel; this thread pumps it
        // into the engine between steps (the engines are synchronous, so the
        // serve loop is the event loop)
        let (tx, rx) = mpsc::channel::<Request>();
        let submitter = Arc::new(ChannelSubmitter::new(tx));
        let mut server = serve_http(http_addr, submitter, http_cfg)?;
        println!("http: listening on {}", server.addr());
        if speculate > 0 {
            let drafter_box: Option<Box<dyn InferenceBackend>> =
                match args.get_or("draft-backend", "native").as_str() {
                    "pjrt" if be.name() == "pjrt" => None,
                    "pjrt" => Some(backend::load(BackendKind::Pjrt)?),
                    "native" if be.name() == "native" => None,
                    "native" => Some(Box::new(NativeBackend::load_default()?)),
                    other => bail!("unknown draft backend {other} (expected native|pjrt)"),
                };
            let drafter: &dyn InferenceBackend =
                drafter_box.as_deref().unwrap_or(be.as_ref());
            let mut engine = SpecEngine::with_drafter(
                drafter,
                be.as_ref(),
                SpecConfig {
                    draft_k: speculate,
                    draft_variant: args.get_or("draft-variant", "fastmamba"),
                    verify_variant: variant.clone(),
                    max_active,
                    reseed_drafter: true,
                },
            )
            .with_policy(sched.clone());
            if let Some(c) = &cache {
                engine = engine.with_cache(Arc::clone(c));
            }
            if let Some(h) = &hub {
                engine = engine
                    .with_telemetry(h.register("0"))
                    .with_flight(Arc::clone(h.flight()), 0);
            }
            if let Some(s) = &trace_sink {
                engine = engine.with_trace(Arc::clone(s), 0);
            }
            engine.metrics.start();
            loop {
                while let Ok(req) = rx.try_recv() {
                    engine.enqueue(req);
                }
                if engine.n_pending() > 0 || engine.n_active() > 0 {
                    engine.step()?;
                } else if http_requests > 0 && engine.finished.len() >= http_requests {
                    break;
                } else {
                    std::thread::sleep(Duration::from_millis(1));
                }
            }
            engine.metrics.stop();
            println!("{}", engine.metrics.summary());
            (engine.finished, engine.metrics)
        } else {
            let mut engine =
                Engine::new(be.as_ref(), EngineConfig { max_active, greedy_chunking: true })
                    .with_policy(sched.clone());
            if let Some(c) = &cache {
                engine = engine.with_cache(Arc::clone(c));
            }
            if let Some(h) = &hub {
                engine = engine
                    .with_telemetry(h.register("0"))
                    .with_flight(Arc::clone(h.flight()), 0);
            }
            if let Some(s) = &trace_sink {
                engine = engine.with_trace(Arc::clone(s), 0);
            }
            engine.metrics.start();
            loop {
                while let Ok(req) = rx.try_recv() {
                    engine.enqueue(req);
                }
                if engine.n_pending() > 0 || engine.n_active() > 0 {
                    engine.step()?;
                } else if http_requests > 0 && engine.finished.len() >= http_requests {
                    break;
                } else {
                    std::thread::sleep(Duration::from_millis(1));
                }
            }
            engine.metrics.stop();
            println!("{}", engine.metrics.summary());
            (engine.finished, engine.metrics)
        }
    };
    if let Some(c) = &cache {
        println!("state cache ({cache_mb} MiB): {}", c.stats().summary());
    }
    print_finish_reasons(&finished);
    ticker_stop.store(true, Ordering::Relaxed);
    if let Some(t) = ticker {
        let _ = t.join();
    }
    if let Some(srv) = metrics_server.as_mut() {
        srv.shutdown();
    }
    if let (Some(sink), Some(path)) = (&trace_sink, trace_out) {
        sink.write(path)?;
        println!("trace: {} events -> {path} ({} dropped)", sink.len(), sink.dropped());
    }
    if let Some(path) = metrics_json {
        std::fs::write(path, json::to_string(&final_metrics.to_json()))?;
        println!("metrics json -> {path}");
    }
    Ok(())
}

fn run_report(args: &Args) -> Result<()> {
    match args.get_or("id", "all").as_str() {
        "all" => report::all()?,
        "table1" => report::table1(),
        "table2" => report::table2(
            args.usize_or("ppl-windows", 6),
            args.usize_or("cloze-items", 16),
        )?,
        "table3" => report::table3(),
        "table4" => report::table4(),
        "table_spec" => report::table_spec(),
        "fig1" => report::fig1(),
        "fig3" => report::fig3(),
        "fig9" => report::fig9(None),
        "fig10" => report::fig10(),
        other => bail!("unknown report id {other}"),
    }
    Ok(())
}

fn simulate(args: &Args) -> Result<()> {
    let model = args.get_or("model", "mamba2-130m");
    let Some(cfg) = ModelConfig::by_name(&model) else {
        bail!("unknown model {model}");
    };
    let pm = PerfModel::new(AcceleratorConfig::default(), cfg.clone());
    let seq_len = args.usize_or("seq-len", 512);
    let batch = args.usize_or("batch", 1);
    let p = pm.prefill(seq_len);
    println!(
        "prefill {model} L={seq_len}: {:.3} ms ({} cycles, bottleneck={}) {:.0} tok/s",
        p.seconds * 1e3,
        p.cycles,
        p.bottleneck,
        p.tokens_per_s
    );
    for (name, frac) in p.breakdown.fractions() {
        println!("  {name:<10} {:.1}%", frac * 100.0);
    }
    let d = pm.decode(batch);
    println!(
        "decode {model} B={batch}: {:.3} ms/step, {:.2} tok/s ({})",
        d.seconds_per_step * 1e3,
        d.tokens_per_s,
        if d.compute_bound { "compute-bound" } else { "DRAM-bound" }
    );
    Ok(())
}

fn info() -> Result<()> {
    let dir = artifacts_dir();
    let have_artifacts = dir.join("manifest.json").exists();
    println!(
        "artifacts dir: {} ({})",
        dir.display(),
        if have_artifacts { "present" } else { "absent — native fallback" }
    );
    let be = backend::load(BackendKind::Auto)?;
    let cfg = be.cfg();
    println!(
        "backend: {} | model: {} (d_model={} layers={} heads={} vocab={})",
        be.name(),
        cfg.name, cfg.d_model, cfg.n_layer, cfg.nheads(), cfg.vocab_size
    );
    println!(
        "prefill buckets {:?}; decode batches {:?}; variants {:?}",
        be.prefill_buckets(),
        be.decode_batches(),
        be.variants()
    );
    if have_artifacts {
        let m = Manifest::load(&dir)?;
        println!("artifacts: {} lowered graphs", m.artifacts.len());
    }
    let acc = AcceleratorConfig::default();
    println!(
        "accelerator: {} MHz, {} linear MAC/cyc, {} conv MAC/cyc, {} ssm ops/cyc",
        acc.clock_hz / 1_000_000,
        acc.linear_macs_per_cycle(),
        acc.conv_macs_per_cycle(),
        acc.ssm_ops_per_cycle()
    );
    Ok(())
}
