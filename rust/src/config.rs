//! Model, fixed-point, and accelerator configurations.
//!
//! Mirrors `python/compile/config.py` (the manifest carries the Python side's
//! values; [`ModelConfig::from_manifest`] cross-checks them) and adds the
//! accelerator instantiation constants from the paper's §IV.

/// Dimensions of a Mamba2 model (SSD variant, `ngroups = 1`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ModelConfig {
    pub name: String,
    pub d_model: usize,
    pub n_layer: usize,
    pub d_state: usize,
    pub headdim: usize,
    pub d_conv: usize,
    pub expand: usize,
    pub ngroups: usize,
    pub vocab_size: usize,
}

impl ModelConfig {
    pub fn d_inner(&self) -> usize {
        self.expand * self.d_model
    }

    pub fn nheads(&self) -> usize {
        self.d_inner() / self.headdim
    }

    /// Channels through the depthwise causal conv (x, B, C concatenated).
    pub fn conv_dim(&self) -> usize {
        self.d_inner() + 2 * self.ngroups * self.d_state
    }

    /// Output width of the input projection (z, xBC, dt).
    pub fn d_in_proj(&self) -> usize {
        2 * self.d_inner() + 2 * self.ngroups * self.d_state + self.nheads()
    }

    /// Flat length of one sequence's rolling pre-conv window,
    /// `(n_layer, d_conv-1, conv_dim)` — the layout every backend, the
    /// state pool, and batch-major decode agree on.
    pub fn conv_state_len(&self) -> usize {
        self.n_layer * (self.d_conv - 1) * self.conv_dim()
    }

    /// Flat length of one sequence's SSM hidden state,
    /// `(n_layer, nheads, headdim, d_state)`.
    pub fn ssm_state_len(&self) -> usize {
        self.n_layer * self.nheads() * self.headdim * self.d_state
    }

    /// Mamba2-130M — the paper's prefill / accuracy model.
    pub fn mamba2_130m() -> Self {
        Self {
            name: "mamba2-130m".into(),
            d_model: 768,
            n_layer: 24,
            d_state: 128,
            headdim: 64,
            d_conv: 4,
            expand: 2,
            ngroups: 1,
            vocab_size: 50288,
        }
    }

    /// Mamba2-2.7B — the paper's decode / energy-efficiency model.
    pub fn mamba2_2_7b() -> Self {
        Self {
            name: "mamba2-2.7b".into(),
            d_model: 2560,
            n_layer: 64,
            d_state: 128,
            headdim: 64,
            d_conv: 4,
            expand: 2,
            ngroups: 1,
            vocab_size: 50288,
        }
    }

    /// The build-time-trained tiny model (serving artifacts).
    pub fn tiny() -> Self {
        Self {
            name: "mamba2-tiny".into(),
            d_model: 256,
            n_layer: 4,
            d_state: 64,
            headdim: 32,
            d_conv: 4,
            expand: 2,
            ngroups: 1,
            vocab_size: 512,
        }
    }

    pub fn by_name(name: &str) -> Option<Self> {
        match name {
            "mamba2-130m" => Some(Self::mamba2_130m()),
            "mamba2-2.7b" => Some(Self::mamba2_2_7b()),
            "mamba2-tiny" => Some(Self::tiny()),
            _ => None,
        }
    }

    /// Parameter count (tied embedding).
    pub fn n_params(&self) -> usize {
        let per_layer = self.d_model // norm_w
            + self.d_in_proj() * self.d_model
            + self.conv_dim() * self.d_conv
            + self.conv_dim()
            + 3 * self.nheads() // dt_bias, a_log, d
            + self.d_inner() // norm_g_w
            + self.d_model * self.d_inner();
        self.vocab_size * self.d_model + self.d_model + self.n_layer * per_layer
    }
}

/// Q-format of the accelerator's 16-bit fixed-point datapath (Q6.10), and
/// the Eq. 3 constants.  Mirrors `FixedPointSpec` in Python; the NAU tests
/// assert bit-identical behaviour across the two implementations.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FixedSpec {
    pub total_bits: u32,
    pub frac_bits: u32,
    pub pwl_segments: u32,
    pub coeff_frac_bits: u32,
}

impl Default for FixedSpec {
    fn default() -> Self {
        Self {
            total_bits: 16,
            frac_bits: 10,
            pwl_segments: 8,
            coeff_frac_bits: 14,
        }
    }
}

impl FixedSpec {
    pub fn scale(&self) -> i32 {
        1 << self.frac_bits
    }

    pub fn qmax(&self) -> i32 {
        (1 << (self.total_bits - 1)) - 1
    }

    pub fn qmin(&self) -> i32 {
        -(1 << (self.total_bits - 1))
    }

    /// log2(e) ~= (1.0111)_2 = 1.4375 exactly, in Q-format (Eq. 3).
    pub fn log2e_fx(&self) -> i32 {
        (1.4375 * self.scale() as f64) as i32
    }
}

/// Instantiation constants of the FastMamba accelerator (paper §IV) plus the
/// VC709 (XC7VX690T) resource budget and clock.
#[derive(Debug, Clone)]
pub struct AcceleratorConfig {
    /// Clock frequency in Hz (paper: 250 MHz).
    pub clock_hz: u64,
    /// Hadamard-based Linear Module: parallel computing groups (paper: 6).
    pub linear_groups: usize,
    /// HAT units per linear group (paper: 4, each 64-wide).
    pub hats_per_group: usize,
    /// Width of each HAT (the Hadamard group size d/m; paper Fig. 6: 64).
    pub hat_width: usize,
    /// MAT units per linear group for the int8 matrix product (paper: 64).
    pub mats_per_group: usize,
    /// int8 MAC lanes per linear MAT (activation vector length; paper: 4).
    pub linear_mat_width: usize,
    /// Convolution Module MAT units (paper: 32).
    pub conv_mats: usize,
    /// Conv kernel size (paper: 4).
    pub conv_kernel: usize,
    /// NAU lane count (paper Fig. 8: 24 x 16b).
    pub nau_lanes: usize,
    /// SSM Step-3 parallel PMU/PMA/MAT units (paper: 32).
    pub ssm_step3_units: usize,
    /// SSM Step-3 per-unit vector width (paper: H^l in R^{32x8}).
    pub ssm_step3_width: usize,
    /// Off-chip memory bandwidth, bytes/s (VC709 DDR3-1866 SODIMM, ~14.9 GB/s).
    pub dram_bw_bytes: f64,
    /// FPGA resource budget (XC7VX690T).
    pub total_lut: u64,
    pub total_ff: u64,
    pub total_dsp: u64,
    pub total_bram36: u64,
}

impl Default for AcceleratorConfig {
    fn default() -> Self {
        Self {
            clock_hz: 250_000_000,
            linear_groups: 6,
            hats_per_group: 4,
            hat_width: 64,
            mats_per_group: 64,
            linear_mat_width: 4,
            conv_mats: 32,
            conv_kernel: 4,
            nau_lanes: 24,
            ssm_step3_units: 32,
            ssm_step3_width: 8,
            dram_bw_bytes: 14.9e9,
            total_lut: 433_200,
            total_ff: 866_400,
            total_dsp: 3_600,
            total_bram36: 1_470,
        }
    }
}

impl AcceleratorConfig {
    /// int8 MACs/cycle of the Hadamard-based Linear Module's MAT array.
    pub fn linear_macs_per_cycle(&self) -> u64 {
        (self.linear_groups * self.mats_per_group * self.linear_mat_width) as u64
    }

    /// MACs/cycle of the Convolution Module.
    pub fn conv_macs_per_cycle(&self) -> u64 {
        (self.conv_mats * self.conv_kernel) as u64
    }

    /// Fixed-point ops/cycle of the SSM module's Step-3 array.
    pub fn ssm_ops_per_cycle(&self) -> u64 {
        (self.ssm_step3_units * self.ssm_step3_width) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dims_130m() {
        let c = ModelConfig::mamba2_130m();
        assert_eq!(c.d_inner(), 1536);
        assert_eq!(c.nheads(), 24); // the SSM module's 24-lane NAU width
        assert_eq!(c.conv_dim(), 1792);
        assert_eq!(c.d_in_proj(), 3352);
    }

    #[test]
    fn dims_tiny() {
        let c = ModelConfig::tiny();
        assert_eq!(c.d_inner(), 512);
        assert_eq!(c.nheads(), 16);
        assert_eq!(c.conv_dim(), 640);
    }

    #[test]
    fn param_count_130m_near_130m() {
        let c = ModelConfig::mamba2_130m();
        let n = c.n_params() as f64;
        assert!(n > 100e6 && n < 180e6, "{n}");
    }

    #[test]
    fn fixed_spec_constants() {
        let s = FixedSpec::default();
        assert_eq!(s.scale(), 1024);
        assert_eq!(s.log2e_fx(), 1472); // 1.4375 * 1024
        assert_eq!(s.qmax(), 32767);
        assert_eq!(s.qmin(), -32768);
    }

    #[test]
    fn accel_throughput_constants() {
        let a = AcceleratorConfig::default();
        assert_eq!(a.linear_macs_per_cycle(), 6 * 64 * 4);
        assert_eq!(a.conv_macs_per_cycle(), 128);
        assert_eq!(a.ssm_ops_per_cycle(), 256);
    }

    #[test]
    fn config_lookup() {
        assert!(ModelConfig::by_name("mamba2-130m").is_some());
        assert!(ModelConfig::by_name("nope").is_none());
    }
}
