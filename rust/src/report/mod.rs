//! Report generators: one function per paper table/figure, each printing
//! the same rows/series the paper reports (shape reproduction — see
//! EXPERIMENTS.md for paper-vs-measured).

use crate::baseline::{CpuBaseline, GpuModel};
use crate::config::{AcceleratorConfig, ModelConfig};
use crate::quant::hadamard::hadamard_transform;
use crate::sim::power::{accelerator_power_w, tokens_per_s_per_w};
use crate::sim::resources::{half_float_nonlinear_unit, nau_unit, utilization};
use crate::sim::{PerfModel, SpecSim};
use crate::util::bench::Table;
use crate::util::rng::Rng;

/// Fig. 1 — GPU prefill runtime breakdown vs sequence length.
pub fn fig1() {
    println!("\n== Fig. 1: GPU prefill runtime breakdown (Mamba2-130M) ==");
    let g = GpuModel::default();
    let cfg = ModelConfig::mamba2_130m();
    let mut t = Table::new(&["seq_len", "linear %", "conv %", "ssm %", "norm+silu %", "total ms"]);
    for l in [64usize, 128, 256, 512, 1024, 2048] {
        let b = g.prefill_breakdown(&cfg, l);
        let f = b.fractions();
        t.row(&[
            l.to_string(),
            format!("{:.1}", f[0].1 * 100.0),
            format!("{:.1}", f[1].1 * 100.0),
            format!("{:.1}", f[2].1 * 100.0),
            format!("{:.1}", f[3].1 * 100.0),
            format!("{:.2}", b.total() * 1e3),
        ]);
    }
    t.print();
    println!("(paper: SSM + linear dominate; SSM share grows with L)");
}

/// Fig. 3 — activation distribution before/after the Hadamard transform.
pub fn fig3() {
    println!("\n== Fig. 3: activation outliers vs Hadamard transform ==");
    let mut rng = Rng::new(42);
    let rows = 256usize;
    let d = 256usize;
    // heavy-tailed activations: a few channels carry large magnitudes
    let mut x = Vec::with_capacity(rows * d);
    for _ in 0..rows {
        let mut row = rng.normal_vec(d, 1.0);
        for c in [7usize, 100, 200] {
            row[c] *= 40.0;
        }
        x.extend(row);
    }
    let stats = |v: &[f32]| -> (f32, f32, f32) {
        let n = v.len() as f32;
        let mean = v.iter().sum::<f32>() / n;
        let var = v.iter().map(|a| (a - mean) * (a - mean)).sum::<f32>() / n;
        let m4 = v.iter().map(|a| (a - mean).powi(4)).sum::<f32>() / n;
        let absmax = v.iter().fold(0.0f32, |m, a| m.max(a.abs()));
        (absmax, var.sqrt(), m4 / (var * var))
    };
    let (mx0, sd0, k0) = stats(&x);
    let xh = hadamard_transform(&x, rows, d, 64);
    let xh_n: Vec<f32> = xh.iter().map(|v| v / (64f32).sqrt()).collect(); // orthonormal view
    let (mx1, sd1, k1) = stats(&xh_n);
    let mut t = Table::new(&["", "absmax", "stddev", "kurtosis", "absmax/std"]);
    t.row(&["before".into(), format!("{mx0:.1}"), format!("{sd0:.2}"),
            format!("{k0:.1}"), format!("{:.1}", mx0 / sd0)]);
    t.row(&["after H".into(), format!("{mx1:.1}"), format!("{sd1:.2}"),
            format!("{k1:.1}"), format!("{:.1}", mx1 / sd1)]);
    t.print();
    println!("(paper: transformed activations concentrate — narrow dynamic range)");
}

/// Fig. 9 — prefill speedup over CPU and GPU across sequence lengths.
pub fn fig9(measured_cpu: Option<&CpuBaseline>) {
    println!("\n== Fig. 9: FastMamba prefill speedup on Mamba2-130M ==");
    let cfg = ModelConfig::mamba2_130m();
    let fpga = PerfModel::new(AcceleratorConfig::default(), cfg.clone());
    let gpu = GpuModel::default();
    let cpu_owned;
    let cpu = match measured_cpu {
        Some(c) => c,
        None => {
            cpu_owned = CpuBaseline::measure();
            &cpu_owned
        }
    };
    let mut t = Table::new(&[
        "seq_len", "fpga ms", "gpu ms", "cpu(calib) ms", "speedup vs gpu", "speedup vs cpu",
    ]);
    let mut max_gpu: f64 = 0.0;
    let mut max_cpu: f64 = 0.0;
    let (mut sum_gpu, mut sum_cpu, mut n) = (0.0f64, 0.0f64, 0.0f64);
    for l in [64usize, 128, 256, 512, 1024, 2048] {
        let f = fpga.prefill(l).seconds;
        let g = gpu.prefill_seconds(&cfg, l);
        let c = cpu.prefill_seconds_calibrated(&cfg, l);
        let sg = g / f;
        let sc = c / f;
        max_gpu = max_gpu.max(sg);
        max_cpu = max_cpu.max(sc);
        sum_gpu += sg;
        sum_cpu += sc;
        n += 1.0;
        t.row(&[
            l.to_string(),
            format!("{:.2}", f * 1e3),
            format!("{:.2}", g * 1e3),
            format!("{:.1}", c * 1e3),
            format!("{sg:.2}x"),
            format!("{sc:.1}x"),
        ]);
    }
    t.print();
    println!(
        "max speedup: {:.1}x CPU / {:.2}x GPU   avg: {:.1}x / {:.2}x   \
         (paper: 68.80x/8.90x max, 55.70x/6.06x avg)",
        max_cpu, max_gpu, sum_cpu / n, sum_gpu / n
    );
}

/// Table III — system configuration + decode throughput / energy efficiency.
pub fn table3() {
    println!("\n== Table III: decode throughput & energy efficiency (Mamba2-2.7B) ==");
    let cfg = ModelConfig::mamba2_2_7b();
    let fpga = PerfModel::new(AcceleratorConfig::default(), cfg.clone());
    let gpu = GpuModel::default();
    let f_tps = fpga.decode(1).tokens_per_s;
    let f_w = accelerator_power_w(&fpga.acc, 0.85);
    let g_tps = gpu.decode_tokens_per_s(&cfg);
    let g_w = gpu.decode_power_w();
    let mut t = Table::new(&["", "GPU (RTX 3090 model)", "FastMamba (sim)"]);
    t.row(&["platform".into(), "8nm, 1395 MHz".into(), "Virtex-7 28nm, 250 MHz".into()]);
    t.row(&["throughput tok/s".into(), format!("{g_tps:.1}"), format!("{f_tps:.2}")]);
    t.row(&["power W".into(), format!("{g_w:.0}"), format!("{f_w:.1}")]);
    let ge = tokens_per_s_per_w(g_tps, g_w);
    let fe = tokens_per_s_per_w(f_tps, f_w);
    t.row(&["tok/(s*W)".into(), format!("{ge:.3}"), format!("{fe:.3}")]);
    t.print();
    println!(
        "energy-efficiency ratio {:.2}x (paper: 1.65x; GPU 111 tok/s @0.37, FPGA 5.68 @0.61)",
        fe / ge
    );
}

/// Table IV — FPGA resource utilization per module.
pub fn table4() {
    println!("\n== Table IV: FastMamba resource utilization (XC7VX690T) ==");
    let u = utilization(&AcceleratorConfig::default());
    let mut t = Table::new(&["Component", "LUT", "FF", "DSP", "BRAM"]);
    for (name, r) in &u.rows {
        t.row(&[
            name.clone(),
            format!("{} ({:.1}%)", r.lut, r.lut as f64 / u.budget.lut as f64 * 100.0),
            format!("{} ({:.1}%)", r.ff, r.ff as f64 / u.budget.ff as f64 * 100.0),
            format!("{} ({:.1}%)", r.dsp, r.dsp as f64 / u.budget.dsp as f64 * 100.0),
            format!("{} ({:.1}%)", r.bram, r.bram as f64 / u.budget.bram as f64 * 100.0),
        ]);
    }
    let r = u.total;
    t.row(&[
        "Total".into(),
        format!("{} ({:.1}%)", r.lut, r.lut as f64 / u.budget.lut as f64 * 100.0),
        format!("{} ({:.1}%)", r.ff, r.ff as f64 / u.budget.ff as f64 * 100.0),
        format!("{} ({:.1}%)", r.dsp, r.dsp as f64 / u.budget.dsp as f64 * 100.0),
        format!("{} ({:.1}%)", r.bram, r.bram as f64 / u.budget.bram as f64 * 100.0),
    ]);
    t.print();
    println!("(paper shape: SSM dominates DSP, Linear dominates LUT, Buffer owns BRAM)");
}

/// Fig. 10 — NAU vs Half-Float Nonlinear Unit resource savings.
pub fn fig10() {
    println!("\n== Fig. 10: NAU vs FP16 nonlinear unit ==");
    let acc = AcceleratorConfig::default();
    let nau = nau_unit(&acc);
    let fp = half_float_nonlinear_unit(&acc);
    let mut t = Table::new(&["", "LUT", "FF", "DSP"]);
    t.row(&["FP16 unit".into(), fp.lut.to_string(), fp.ff.to_string(), fp.dsp.to_string()]);
    t.row(&["NAU".into(), nau.lut.to_string(), nau.ff.to_string(), nau.dsp.to_string()]);
    t.row(&[
        "saving".into(),
        format!("{:.0}%", (1.0 - nau.lut as f64 / fp.lut as f64) * 100.0),
        format!("{:.0}%", (1.0 - nau.ff as f64 / fp.ff as f64) * 100.0),
        format!("{:.0}%", (1.0 - nau.dsp as f64 / fp.dsp as f64) * 100.0),
    ]);
    t.print();
    println!("(paper: 56% DSP / 49% FF saved)");
}

/// Table II — quantization accuracy (delegates to the eval harness on the
/// native backend: trained checkpoint + held-out corpus when `artifacts/`
/// is present, deterministic synthetic stand-ins otherwise).
pub fn table2(ppl_windows: usize, cloze_items: usize) -> anyhow::Result<()> {
    use crate::backend::{InferenceBackend, NativeBackend};
    println!("\n== Table II: W8A8 quantization accuracy (tiny Mamba2) ==");
    let be = NativeBackend::load_default()?;
    if be.artifacts_dir().is_none() {
        println!("(no artifacts: synthetic weights + corpus — ordering only)");
    }
    let corpus = crate::eval::corpus_for(&be);
    let rows = crate::eval::table2(&be, &corpus, ppl_windows, cloze_items)?;
    let mut headers: Vec<&str> = vec!["Method", "PPL", "logit RMSE"];
    let names: Vec<String> = crate::eval::TASKS.iter().map(|t| t.0.to_string()).collect();
    for n in &names {
        headers.push(n);
    }
    headers.push("Avg ACC");
    let mut t = Table::new(&headers);
    for r in &rows {
        let mut cells = vec![
            r.method.clone(),
            format!("{:.2}", r.ppl),
            format!("{:.4}", r.logit_rmse),
        ];
        for (_, acc) in &r.task_acc {
            cells.push(format!("{:.1}", acc * 100.0));
        }
        cells.push(format!("{:.1}", r.avg_acc * 100.0));
        t.row(&cells);
    }
    t.print();
    println!("(paper ordering: NormalQ << SmoothQ < FastMamba-LQ ~= FP16; FastMamba within ~1%)");
    Ok(())
}

/// Speculative decoding — baseline vs speculative decode throughput on the
/// accelerator model, across draft length k and acceptance rate.
pub fn table_spec() {
    println!(
        "\n== Speculative decode: baseline vs draft-k/verify-1 throughput \
         (Mamba2-2.7B, VC709 sim) =="
    );
    let sim = SpecSim::new(AcceleratorConfig::default(), ModelConfig::mamba2_2_7b());
    let base = sim.perf.decode(1);
    println!(
        "baseline decode: {:.2} tok/s ({}; drafter step = {:.2}x a verifier step)",
        base.tokens_per_s,
        if base.compute_bound { "compute-bound" } else { "DRAM-bound" },
        sim.draft_cost_ratio
    );
    let accepts = [0.5f64, 0.7, 0.8, 0.9, 0.95];
    let mut headers: Vec<String> = vec!["k".into()];
    for p in accepts {
        headers.push(format!("accept {p:.2}"));
    }
    headers.push("break-even".into());
    let hdr_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(&hdr_refs);
    for k in [2usize, 4, 8] {
        let mut row = vec![k.to_string()];
        for p in accepts {
            let pt = sim.point(k, p);
            row.push(format!("{:.2} tok/s ({:.2}x)", pt.tokens_per_s, pt.speedup));
        }
        row.push(match sim.break_even_acceptance(k) {
            Some(p) => format!("p >= {p:.2}"),
            None => "never".into(),
        });
        t.row(&row);
    }
    t.print();
    println!(
        "(serve-time acceptance of the int8+PoT drafter is reported by \
         `serve --speculate K`; see examples/spec_decode.rs for measured speedup)"
    );
}

/// Table I — VPU configuration echo (sanity documentation).
pub fn table1() {
    println!("\n== Table I: VPU function configuration ==");
    let mut t = Table::new(&["VPU", "inputs", "output", "function"]);
    t.row(&["PAU".into(), "A:n, B:n".into(), "P:n".into(), "A + B".into()]);
    t.row(&["PMU".into(), "A:n, B:n".into(), "P:n".into(), "A × B".into()]);
    t.row(&["PMA".into(), "A:n, B:n, C:n".into(), "P:n".into(), "A × B + C".into()]);
    t.row(&["HAT".into(), "A:n".into(), "P:1".into(), "Σ A_i".into()]);
    t.row(&["MAT".into(), "A:n, B:n".into(), "P:1".into(), "Σ A_i × B_i".into()]);
    t.print();
}

/// Everything.
pub fn all() -> anyhow::Result<()> {
    table1();
    fig1();
    fig3();
    table2(6, 16)?;
    fig9(None);
    table3();
    table_spec();
    table4();
    fig10();
    Ok(())
}
