//! Bucket arithmetic shared by every execution backend and the coordinator:
//! AOT artifacts exist only at fixed prefill lengths / decode batch sizes,
//! so arbitrary workloads are covered by chunking (full buckets + remainder)
//! or padding (smallest covering bucket).

/// Smallest bucket that covers `n` items, from an ascending bucket list;
/// `None` when even the largest bucket is too small.  Shared by the decode
/// batcher (batch buckets) and the speculative engine (verify windows over
/// the prefill buckets).
pub fn smallest_covering(buckets_ascending: &[usize], n: usize) -> Option<usize> {
    buckets_ascending.iter().copied().find(|b| *b >= n)
}

/// Cover `n` items with full buckets, largest first; returns the chunk
/// list and the remainder (always smaller than the smallest bucket).
/// Shared by the engine's chunked-prefill admission, the speculative
/// engine's verifier-debt consolidation, and the default
/// [`InferenceBackend::forward_logits`](super::InferenceBackend::forward_logits)
/// implementation.
pub fn full_bucket_plan(buckets_ascending: &[usize], n: usize) -> (Vec<usize>, usize) {
    let mut chunks = Vec::new();
    let mut rest = n;
    for &b in buckets_ascending.iter().rev() {
        while rest >= b {
            chunks.push(b);
            rest -= b;
        }
    }
    (chunks, rest)
}
