//! The execution API: one trait every serving layer programs against.
//!
//! [`InferenceBackend`] is the single contract between the coordinator
//! stack ([`crate::coordinator`]: `Engine`, `SpecEngine`, `serve_threaded`)
//! and whatever actually runs the model.  Two first-class implementations
//! ship today:
//!
//! * [`PjrtBackend`] (`pjrt` cargo feature, on by default) — the AOT-lowered
//!   HLO artifacts executed through the XLA PJRT client
//!   ([`crate::runtime::Runtime`]).  Fastest on this host; requires
//!   `artifacts/manifest.json` (run `make artifacts`) and a local
//!   `xla_extension` install at build time.
//! * [`NativeBackend`] — the in-process Rust Mamba2 golden model
//!   ([`crate::model::Mamba2`]).  Artifact-free: loads the trained
//!   checkpoint when `artifacts/` is present and falls back to
//!   deterministic synthetic weights otherwise, so every engine path (and
//!   its tests) runs on any machine, including hosts with no XLA and no
//!   Python toolchain.
//!
//! The contract is bucket-shaped because the PJRT artifacts are: `prefill`
//! consumes exact bucket-length chunks with explicit state chaining, and
//! `decode` consumes batch-major state for one of the compiled batch sizes.
//! `NativeBackend` accepts *arbitrary* lengths and batch sizes but honours
//! the same call shapes, so the coordinator code is identical over both.
//! Future backends (multi-device PJRT, a real FPGA bridge, remote workers)
//! implement the same six methods and inherit the whole serving stack —
//! and the whole contract test surface: [`conformance`] is a reusable
//! assertion harness (chunking equivalence, batched-decode token
//! exactness, `forward_logits` chaining, bucket sanity, variant coverage,
//! state shapes) instantiated unconditionally for `NativeBackend` and
//! artifact-gated for `PjrtBackend`.

pub mod bucket;
pub mod conformance;
pub mod native;
#[cfg(feature = "pjrt")]
pub mod pjrt;

pub use native::NativeBackend;
#[cfg(feature = "pjrt")]
pub use pjrt::PjrtBackend;

use std::path::Path;

use anyhow::Result;

use crate::config::ModelConfig;

/// Output of one prefill call over a token chunk.
#[derive(Debug, Clone)]
pub struct PrefillOut {
    /// (L, vocab) row-major — exact per-position logits
    pub logits: Vec<f32>,
    /// (n_layer, d_conv-1, conv_dim)
    pub conv_state: Vec<f32>,
    /// (n_layer, nheads, headdim, d_state)
    pub ssm_state: Vec<f32>,
}

/// Output of one batched decode step.
#[derive(Debug, Clone)]
pub struct DecodeOut {
    /// (B, vocab)
    pub logits: Vec<f32>,
    /// (B, n_layer, d_conv-1, conv_dim)
    pub conv_state: Vec<f32>,
    /// (B, n_layer, nheads, headdim, d_state)
    pub ssm_state: Vec<f32>,
}

/// One execution backend: prefill/decode over explicit recurrent state.
///
/// State is carried *by the caller* (flat `conv`/`ssm` buffers, the same
/// layout [`crate::coordinator::StatePool`] pools), so engines can gather,
/// scatter, snapshot, and roll back without the backend's involvement —
/// the property speculative decoding depends on.
pub trait InferenceBackend {
    /// Short identifier ("pjrt", "native") for logs and CLI output.
    fn name(&self) -> &'static str;

    /// The model this backend serves.
    fn cfg(&self) -> &ModelConfig;

    /// Quantization variants this backend can execute.
    fn variants(&self) -> Vec<String>;

    /// The artifacts directory backing this backend, when there is one
    /// (used to locate side-band data such as the held-out corpus).
    fn artifacts_dir(&self) -> Option<&Path> {
        None
    }

    /// Zero-initialized (conv, ssm) state pair for a fresh sequence.
    fn zero_state(&self) -> (Vec<f32>, Vec<f32>) {
        let cfg = self.cfg();
        (
            vec![0.0; cfg.conv_state_len()],
            vec![0.0; cfg.ssm_state_len()],
        )
    }

    /// Run prefill over one chunk, continuing from `(conv_state, ssm_state)`
    /// (zeros for a fresh sequence — chunked prefill chains exactly).
    /// PJRT requires `tokens.len()` to be a compiled bucket length; the
    /// native backend accepts any length.
    fn prefill(
        &self,
        variant: &str,
        tokens: &[i32],
        conv_state: &[f32],
        ssm_state: &[f32],
    ) -> Result<PrefillOut>;

    /// Prefill a fresh sequence (zero state).
    fn prefill_fresh(&self, variant: &str, tokens: &[i32]) -> Result<PrefillOut> {
        let (c, s) = self.zero_state();
        self.prefill(variant, tokens, &c, &s)
    }

    /// One batched decode step.  All state slices are batch-major;
    /// `tokens.len() == batch`.  PJRT requires `batch` to be a compiled
    /// bucket; the native backend accepts any batch size.
    fn decode(
        &self,
        variant: &str,
        batch: usize,
        conv_state: &[f32],
        ssm_state: &[f32],
        tokens: &[i32],
    ) -> Result<DecodeOut>;

    /// Prefill chunk lengths this backend executes (ascending).
    fn prefill_buckets(&self) -> Vec<usize>;

    /// Decode batch sizes this backend executes (ascending).
    fn decode_batches(&self) -> Vec<usize>;

    /// Pre-compile / pre-warm everything the listed variants need, so the
    /// request path never pays one-time costs.  No-op where nothing is
    /// lazily compiled (the native backend).
    fn warmup(&self, _variants: &[String]) -> Result<()> {
        Ok(())
    }

    /// Exact per-position logits `(L, vocab)` for an arbitrary-length
    /// sequence from a fresh state: full prefill buckets first, then the
    /// sub-bucket remainder through single-token decode steps — the same
    /// exact chaining the engine's admission path uses.  Backends with
    /// unrestricted prefill lengths override this with a single call.
    fn forward_logits(&self, variant: &str, tokens: &[i32]) -> Result<Vec<f32>> {
        let vocab = self.cfg().vocab_size;
        let (mut conv, mut ssm) = self.zero_state();
        let buckets = self.prefill_buckets();
        let (chunks, rest) = bucket::full_bucket_plan(&buckets, tokens.len());
        let mut logits = Vec::with_capacity(tokens.len() * vocab);
        let mut off = 0usize;
        for b in chunks {
            let out = self.prefill(variant, &tokens[off..off + b], &conv, &ssm)?;
            conv = out.conv_state;
            ssm = out.ssm_state;
            logits.extend(out.logits);
            off += b;
        }
        for i in off..off + rest {
            let out = self.decode(variant, 1, &conv, &ssm, &tokens[i..i + 1])?;
            conv = out.conv_state;
            ssm = out.ssm_state;
            logits.extend(out.logits);
        }
        Ok(logits)
    }
}

/// Which backend to load — the CLI's `--backend` flag.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    /// PJRT when the build has it and artifacts exist, native otherwise.
    Auto,
    /// The in-process Rust model (artifact-free).
    Native,
    /// The AOT artifacts through the XLA PJRT client.
    Pjrt,
}

impl BackendKind {
    pub fn from_name(s: &str) -> Option<Self> {
        match s {
            "auto" => Some(Self::Auto),
            "native" => Some(Self::Native),
            "pjrt" => Some(Self::Pjrt),
            _ => None,
        }
    }
}

#[cfg(feature = "pjrt")]
fn load_pjrt() -> Result<Box<dyn InferenceBackend>> {
    Ok(Box::new(PjrtBackend::load_default()?))
}

#[cfg(not(feature = "pjrt"))]
fn load_pjrt() -> Result<Box<dyn InferenceBackend>> {
    anyhow::bail!(
        "this build has no PJRT backend: rebuild with `--features pjrt` \
         (needs a local xla_extension), or use `--backend native`"
    )
}

#[cfg(feature = "pjrt")]
fn pjrt_artifacts_present() -> bool {
    crate::model::weights::artifacts_dir().join("manifest.json").exists()
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_artifacts_present() -> bool {
    false
}

/// Load a backend by kind.  `Auto` prefers PJRT (compiled artifacts) and
/// falls back to the native model, so every entry point works on a host
/// with no artifacts and no xla_extension.
pub fn load(kind: BackendKind) -> Result<Box<dyn InferenceBackend>> {
    match kind {
        BackendKind::Pjrt => load_pjrt(),
        BackendKind::Native => Ok(Box::new(NativeBackend::load_default()?)),
        BackendKind::Auto => {
            if pjrt_artifacts_present() {
                load_pjrt()
            } else {
                Ok(Box::new(NativeBackend::load_default()?))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_kind_parses() {
        assert_eq!(BackendKind::from_name("auto"), Some(BackendKind::Auto));
        assert_eq!(BackendKind::from_name("native"), Some(BackendKind::Native));
        assert_eq!(BackendKind::from_name("pjrt"), Some(BackendKind::Pjrt));
        assert_eq!(BackendKind::from_name("tpu"), None);
    }

    #[test]
    fn load_native_always_works() {
        let be = load(BackendKind::Native).expect("native backend");
        assert_eq!(be.name(), "native");
        assert!(be.cfg().vocab_size > 0);
        assert!(!be.prefill_buckets().is_empty());
        assert!(!be.decode_batches().is_empty());
        be.warmup(&be.variants()).unwrap();
    }

    /// Wrapper that hides the native backend's arbitrary-length prefill so
    /// the trait's *default* chunked `forward_logits` path is exercised.
    struct Bucketed(NativeBackend);

    impl InferenceBackend for Bucketed {
        fn name(&self) -> &'static str {
            "bucketed-test"
        }
        fn cfg(&self) -> &ModelConfig {
            self.0.cfg()
        }
        fn variants(&self) -> Vec<String> {
            self.0.variants()
        }
        fn prefill(
            &self,
            variant: &str,
            tokens: &[i32],
            conv_state: &[f32],
            ssm_state: &[f32],
        ) -> Result<PrefillOut> {
            assert!(
                self.prefill_buckets().contains(&tokens.len()),
                "default forward_logits must send exact bucket lengths, got {}",
                tokens.len()
            );
            self.0.prefill(variant, tokens, conv_state, ssm_state)
        }
        fn decode(
            &self,
            variant: &str,
            batch: usize,
            conv_state: &[f32],
            ssm_state: &[f32],
            tokens: &[i32],
        ) -> Result<DecodeOut> {
            self.0.decode(variant, batch, conv_state, ssm_state, tokens)
        }
        fn prefill_buckets(&self) -> Vec<usize> {
            vec![8, 16]
        }
        fn decode_batches(&self) -> Vec<usize> {
            vec![1, 2]
        }
    }

    #[test]
    fn default_forward_logits_chunks_exactly() {
        // 21 tokens over buckets {8, 16} -> chunks [16] + 5 decode steps;
        // must match the native one-shot prefill per position
        let be = Bucketed(NativeBackend::synthetic(3));
        let vocab = be.cfg().vocab_size;
        let tokens: Vec<i32> = (0..21).map(|i| (i * 13) % vocab as i32).collect();
        let chunked = be.forward_logits("fp32", &tokens).unwrap();
        let full = be.0.forward_logits("fp32", &tokens).unwrap();
        assert_eq!(chunked.len(), tokens.len() * vocab);
        let mut max_err = 0.0f32;
        for (a, b) in chunked.iter().zip(&full) {
            max_err = max_err.max((a - b).abs());
        }
        assert!(max_err < 1e-3, "default vs native forward_logits err {max_err}");
    }
}
