//! The artifact-free execution backend: the in-process Rust Mamba2 golden
//! model behind the [`InferenceBackend`] contract.
//!
//! Everything the PJRT artifacts can do, this does without them: all five
//! quantization variants, chunked prefill with exact state chaining
//! ([`Mamba2::prefill_chunk`]), and batch-major decode at *arbitrary* batch
//! sizes ([`Mamba2::decode_batch`] steps every sequence through the
//! `[batch, state]` buffers in one pass — no compiled bucket constraint,
//! no per-sequence state copies).  It loads the trained tiny
//! checkpoint when `artifacts/` is present and deterministic synthetic
//! weights otherwise, which is what lets the whole coordinator stack run —
//! and be tested, unconditionally — on hosts with no XLA, no artifacts,
//! and no Python.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, ensure, Result};

use crate::config::ModelConfig;
use crate::model::mamba2::DecodeState;
use crate::model::weights::{artifacts_dir, Manifest, ModelWeights};
use crate::model::{Mamba2, Variant};

use super::{DecodeOut, InferenceBackend, PrefillOut};

/// Seed for the synthetic-weights fallback.  One fixed value so every
/// artifact-free `NativeBackend::load_default()` in a process (serve
/// backend, drafter, test baseline) sees *identical* weights.
pub const SYNTHETIC_SEED: u64 = 3;

/// Default bucket lists when no manifest dictates them — mirrors
/// `PREFILL_LENS` / `DECODE_BATCHES` in `python/compile/aot.py` so chunk
/// plans and batch packing behave the same on either backend.
const DEFAULT_PREFILL_BUCKETS: [usize; 4] = [32, 64, 128, 256];
const DEFAULT_DECODE_BATCHES: [usize; 4] = [1, 2, 4, 8];

pub struct NativeBackend {
    model: Mamba2,
    prefill_buckets: Vec<usize>,
    decode_batches: Vec<usize>,
    dir: Option<PathBuf>,
}

impl NativeBackend {
    /// Wrap a model (Hadamard weights prepared once, like the FPGA's
    /// offline weight preprocessing) with the default bucket lists.
    pub fn new(weights: ModelWeights) -> Self {
        let mut model = Mamba2::new(weights);
        model.prepare();
        Self {
            model,
            prefill_buckets: DEFAULT_PREFILL_BUCKETS.to_vec(),
            decode_batches: DEFAULT_DECODE_BATCHES.to_vec(),
            dir: None,
        }
    }

    /// Override the advertised buckets (the backend itself accepts any
    /// length/batch; the lists steer the coordinator's planning).
    pub fn with_buckets(mut self, prefill: Vec<usize>, decode: Vec<usize>) -> Self {
        assert!(!prefill.is_empty() && !decode.is_empty());
        self.prefill_buckets = prefill;
        self.decode_batches = decode;
        self.prefill_buckets.sort_unstable();
        self.decode_batches.sort_unstable();
        self
    }

    /// Deterministic synthetic tiny model — what tests and artifact-free
    /// hosts run.
    pub fn synthetic(seed: u64) -> Self {
        Self::new(ModelWeights::random(&ModelConfig::tiny(), seed))
    }

    /// Trained checkpoint from `artifacts/` when present (adopting the
    /// manifest's bucket lists so plans match the PJRT backend exactly),
    /// synthetic weights otherwise.
    pub fn load_default() -> Result<Self> {
        let dir = artifacts_dir();
        if dir.join("manifest.json").exists() {
            let weights = ModelWeights::load(&dir)?;
            let manifest = Manifest::load(&dir)?;
            let mut be = Self::new(weights)
                .with_buckets(manifest.prefill_lens, manifest.decode_batches);
            be.dir = Some(dir);
            Ok(be)
        } else {
            Ok(Self::synthetic(SYNTHETIC_SEED))
        }
    }

    pub fn model(&self) -> &Mamba2 {
        &self.model
    }

    fn variant(&self, name: &str) -> Result<Variant> {
        Variant::from_name(name).ok_or_else(|| anyhow!("unknown variant {name}"))
    }

    fn conv_len(&self) -> usize {
        self.cfg().conv_state_len()
    }

    fn ssm_len(&self) -> usize {
        self.cfg().ssm_state_len()
    }
}

impl InferenceBackend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn cfg(&self) -> &ModelConfig {
        &self.model.w.cfg
    }

    fn variants(&self) -> Vec<String> {
        Variant::ALL.iter().map(|v| v.name().to_string()).collect()
    }

    fn artifacts_dir(&self) -> Option<&Path> {
        self.dir.as_deref()
    }

    fn prefill(
        &self,
        variant: &str,
        tokens: &[i32],
        conv_state: &[f32],
        ssm_state: &[f32],
    ) -> Result<PrefillOut> {
        let v = self.variant(variant)?;
        ensure!(!tokens.is_empty(), "empty prefill chunk");
        ensure!(conv_state.len() == self.conv_len(), "conv state length");
        ensure!(ssm_state.len() == self.ssm_len(), "ssm state length");
        let mut state =
            DecodeState { conv: conv_state.to_vec(), ssm: ssm_state.to_vec() };
        let toks: Vec<u32> = tokens.iter().map(|t| *t as u32).collect();
        let logits = self.model.prefill_chunk(&toks, v, &mut state);
        Ok(PrefillOut { logits, conv_state: state.conv, ssm_state: state.ssm })
    }

    fn decode(
        &self,
        variant: &str,
        batch: usize,
        conv_state: &[f32],
        ssm_state: &[f32],
        tokens: &[i32],
    ) -> Result<DecodeOut> {
        let v = self.variant(variant)?;
        ensure!(tokens.len() == batch, "tokens.len() != batch");
        let (cl, sl) = (self.conv_len(), self.ssm_len());
        ensure!(conv_state.len() == batch * cl, "conv state length");
        ensure!(ssm_state.len() == batch * sl, "ssm state length");
        // batch-major in one pass: the caller's state is copied once into
        // the output buffers and every sequence steps through them in place
        // (`Mamba2::decode_batch`) — no per-sequence DecodeState marshalling,
        // one weight stream per step for the whole batch
        let mut out_conv = conv_state.to_vec();
        let mut out_ssm = ssm_state.to_vec();
        let toks: Vec<u32> = tokens.iter().map(|t| *t as u32).collect();
        let logits = self.model.decode_batch(&toks, v, &mut out_conv, &mut out_ssm);
        Ok(DecodeOut { logits, conv_state: out_conv, ssm_state: out_ssm })
    }

    fn prefill_buckets(&self) -> Vec<usize> {
        self.prefill_buckets.clone()
    }

    fn decode_batches(&self) -> Vec<usize> {
        self.decode_batches.clone()
    }

    fn forward_logits(&self, variant: &str, tokens: &[i32]) -> Result<Vec<f32>> {
        // no bucket constraint in-process: one exact full-length prefill
        let out = self.prefill_fresh(variant, tokens)?;
        Ok(out.logits)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::argmax;

    fn be() -> NativeBackend {
        NativeBackend::synthetic(SYNTHETIC_SEED)
    }

    fn toks(n: usize, vocab: usize, seed: usize) -> Vec<i32> {
        (0..n).map(|i| ((i * 17 + seed * 131) % vocab) as i32).collect()
    }

    #[test]
    fn chunked_prefill_matches_one_shot() {
        let be = be();
        let vocab = be.cfg().vocab_size;
        let t = toks(80, vocab, 1);
        let full = be.prefill_fresh("fp32", &t).unwrap();
        let (mut conv, mut ssm) = be.zero_state();
        let mut logits = Vec::new();
        for chunk in [&t[..32], &t[32..64], &t[64..]] {
            let out = be.prefill("fp32", chunk, &conv, &ssm).unwrap();
            conv = out.conv_state;
            ssm = out.ssm_state;
            logits.extend(out.logits);
        }
        let mut max_err = 0.0f32;
        for (a, b) in logits.iter().zip(&full.logits) {
            max_err = max_err.max((a - b).abs());
        }
        assert!(max_err < 1e-4, "chunked vs full logits err {max_err}");
        let mut s_err = 0.0f32;
        for (a, b) in ssm.iter().zip(&full.ssm_state) {
            s_err = s_err.max((a - b).abs());
        }
        assert!(s_err < 1e-4, "chunked vs full state err {s_err}");
    }

    #[test]
    fn batched_decode_matches_singles() {
        let be = be();
        let vocab = be.cfg().vocab_size;
        // independent per-sequence states from three different prompts
        let mut convs = Vec::new();
        let mut ssms = Vec::new();
        let mut next = Vec::new();
        for s in 0..3usize {
            let t = toks(32, vocab, s + 2);
            let out = be.prefill_fresh("fp32", &t).unwrap();
            convs.push(out.conv_state);
            ssms.push(out.ssm_state);
            next.push(*t.last().unwrap());
        }
        let conv_b: Vec<f32> = convs.concat();
        let ssm_b: Vec<f32> = ssms.concat();
        let batched = be.decode("fp32", 3, &conv_b, &ssm_b, &next).unwrap();
        for s in 0..3 {
            let single = be
                .decode("fp32", 1, &convs[s], &ssms[s], &next[s..s + 1])
                .unwrap();
            assert_eq!(
                single.logits,
                batched.logits[s * vocab..(s + 1) * vocab].to_vec(),
                "seq {s} logits"
            );
            let cl = convs[s].len();
            let sl = ssms[s].len();
            assert_eq!(single.conv_state, batched.conv_state[s * cl..(s + 1) * cl]);
            assert_eq!(single.ssm_state, batched.ssm_state[s * sl..(s + 1) * sl]);
        }
    }

    #[test]
    fn arbitrary_batch_and_chunk_sizes_accepted() {
        // no compiled-bucket constraint: batch 5 and a 7-token chunk work
        let be = be();
        let vocab = be.cfg().vocab_size;
        let (conv, ssm) = be.zero_state();
        let conv5: Vec<f32> = conv.repeat(5);
        let ssm5: Vec<f32> = ssm.repeat(5);
        let out = be.decode("fp32", 5, &conv5, &ssm5, &[1, 2, 3, 4, 5]).unwrap();
        assert_eq!(out.logits.len(), 5 * vocab);
        let out = be.prefill_fresh("fp32", &toks(7, vocab, 4)).unwrap();
        assert_eq!(out.logits.len(), 7 * vocab);
    }

    #[test]
    fn all_variants_execute() {
        let be = be();
        let vocab = be.cfg().vocab_size;
        let t = toks(16, vocab, 5);
        for v in be.variants() {
            let out = be.prefill_fresh(&v, &t).unwrap();
            assert!(out.logits.iter().all(|x| x.is_finite()), "{v}");
            let d = be
                .decode(&v, 1, &out.conv_state, &out.ssm_state, &t[15..])
                .unwrap();
            assert!(d.logits.iter().all(|x| x.is_finite()), "{v}");
        }
        assert!(be.prefill_fresh("nosuch", &t).is_err());
    }

    #[test]
    fn prefill_then_decode_token_exact_with_forward_logits() {
        let be = be();
        let vocab = be.cfg().vocab_size;
        let t = toks(40, vocab, 6);
        let all = be.forward_logits("fp32", &t).unwrap();
        let pre = be.prefill_fresh("fp32", &t[..39]).unwrap();
        let step = be
            .decode("fp32", 1, &pre.conv_state, &pre.ssm_state, &t[39..])
            .unwrap();
        assert_eq!(
            argmax(&step.logits),
            argmax(&all[39 * vocab..40 * vocab]),
            "decode continuation must agree with full forward"
        );
    }

    #[test]
    fn synthetic_backend_is_deterministic() {
        let a = NativeBackend::synthetic(7);
        let b = NativeBackend::synthetic(7);
        let t = toks(8, a.cfg().vocab_size, 7);
        assert_eq!(
            a.prefill_fresh("fp32", &t).unwrap().logits,
            b.prefill_fresh("fp32", &t).unwrap().logits
        );
    }
}
