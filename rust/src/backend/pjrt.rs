//! The PJRT execution backend: the AOT-lowered HLO artifacts run through
//! the XLA CPU client, adapted to the [`InferenceBackend`] contract.
//!
//! A thin delegation layer over [`Runtime`] — the runtime keeps owning
//! executable compilation/caching and weight literals; this type only maps
//! the trait's variant-level `warmup` onto artifact names and exposes the
//! manifest's bucket lists.  Compiled only under the `pjrt` cargo feature
//! (the `xla` crate needs a local `xla_extension` install).

use std::path::{Path, PathBuf};

use anyhow::Result;

use crate::config::ModelConfig;
use crate::runtime::Runtime;

use super::{DecodeOut, InferenceBackend, PrefillOut};

pub struct PjrtBackend {
    rt: Runtime,
}

impl PjrtBackend {
    /// Load over the default artifacts directory (`FASTMAMBA_ARTIFACTS` or
    /// the nearest `artifacts/manifest.json`).
    pub fn load_default() -> Result<Self> {
        Ok(Self { rt: Runtime::load_default()? })
    }

    pub fn load(dir: PathBuf) -> Result<Self> {
        Ok(Self { rt: Runtime::load(dir)? })
    }

    pub fn from_runtime(rt: Runtime) -> Self {
        Self { rt }
    }

    /// The underlying runtime (executable cache inspection, manifest).
    pub fn runtime(&self) -> &Runtime {
        &self.rt
    }
}

impl InferenceBackend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn cfg(&self) -> &ModelConfig {
        &self.rt.weights_host.cfg
    }

    fn variants(&self) -> Vec<String> {
        self.rt.manifest.variants.clone()
    }

    fn artifacts_dir(&self) -> Option<&Path> {
        Some(&self.rt.dir)
    }

    fn zero_state(&self) -> (Vec<f32>, Vec<f32>) {
        self.rt.zero_state()
    }

    fn prefill(
        &self,
        variant: &str,
        tokens: &[i32],
        conv_state: &[f32],
        ssm_state: &[f32],
    ) -> Result<PrefillOut> {
        self.rt.prefill(variant, tokens, conv_state, ssm_state)
    }

    fn decode(
        &self,
        variant: &str,
        batch: usize,
        conv_state: &[f32],
        ssm_state: &[f32],
        tokens: &[i32],
    ) -> Result<DecodeOut> {
        self.rt.decode(variant, batch, conv_state, ssm_state, tokens)
    }

    fn prefill_buckets(&self) -> Vec<usize> {
        self.rt.prefill_buckets()
    }

    fn decode_batches(&self) -> Vec<usize> {
        self.rt.decode_batches()
    }

    fn warmup(&self, variants: &[String]) -> Result<()> {
        let cfg = self.cfg();
        let mut names = Vec::new();
        for v in variants {
            for l in self.prefill_buckets() {
                names.push(format!("{}_prefill_{}_L{}", cfg.name, v, l));
            }
            for b in self.decode_batches() {
                names.push(format!("{}_decode_{}_B{}", cfg.name, v, b));
            }
        }
        // warm only what the manifest actually lowered
        names.retain(|n| self.rt.manifest.artifact(n).is_some());
        self.rt.warmup(&names)
    }
}

/// Backend-parity suite (satellite): the native golden model and the PJRT
/// executables must be *token-exact* on the fp32 variant — same argmax at
/// every prefill position and along a decode chain.  Gated on artifacts.
#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::NativeBackend;
    use crate::coordinator::request::argmax;
    use crate::model::weights::artifacts_dir;

    fn both() -> Option<(NativeBackend, PjrtBackend)> {
        if !artifacts_dir().join("manifest.json").exists() {
            return None;
        }
        Some((
            NativeBackend::load_default().expect("native load"),
            PjrtBackend::load_default().expect("pjrt load"),
        ))
    }

    #[test]
    fn warmup_compiles_fp32_graphs() {
        let Some((_, pj)) = both() else { return };
        pj.warmup(&["fp32".to_string()]).expect("warmup");
        let max = pj.prefill_buckets().len() + pj.decode_batches().len();
        let got = pj.runtime().compiled_count();
        assert!(got > 0 && got <= max, "warmed {got} of <= {max} artifacts");
        // warming again must not recompile
        pj.warmup(&["fp32".to_string()]).expect("warmup");
        assert_eq!(pj.runtime().compiled_count(), got);
    }

    #[test]
    fn prefill_token_exact_fp32() {
        let Some((na, pj)) = both() else { return };
        assert_eq!(na.cfg(), pj.cfg());
        let vocab = pj.cfg().vocab_size;
        let tokens: Vec<i32> = (0..64).map(|i| (i * 7) % vocab as i32).collect();
        let n = na.prefill_fresh("fp32", &tokens).unwrap();
        let p = pj.prefill_fresh("fp32", &tokens).unwrap();
        for t in 0..tokens.len() {
            assert_eq!(
                argmax(&n.logits[t * vocab..(t + 1) * vocab]),
                argmax(&p.logits[t * vocab..(t + 1) * vocab]),
                "prefill position {t}"
            );
        }
    }

    #[test]
    fn chunked_prefill_state_parity() {
        // chain two buckets through both backends: final states must agree
        // to runtime tolerance and next-token argmax must match
        let Some((na, pj)) = both() else { return };
        let vocab = pj.cfg().vocab_size;
        let tokens: Vec<i32> = (0..96).map(|i| (i * 5) % vocab as i32).collect();
        let run = |be: &dyn InferenceBackend| {
            let (mut conv, mut ssm) = be.zero_state();
            for chunk in [&tokens[..64], &tokens[64..]] {
                let out = be.prefill("fp32", chunk, &conv, &ssm).unwrap();
                conv = out.conv_state;
                ssm = out.ssm_state;
            }
            be.decode("fp32", 1, &conv, &ssm, &tokens[95..]).unwrap()
        };
        let n = run(&na);
        let p = run(&pj);
        assert_eq!(argmax(&n.logits), argmax(&p.logits));
        let mut s_err = 0.0f32;
        for (a, b) in n.ssm_state.iter().zip(&p.ssm_state) {
            s_err = s_err.max((a - b).abs());
        }
        assert!(s_err < 2e-2, "chained state err {s_err}");
    }

    #[test]
    fn decode_chain_token_exact_fp32() {
        let Some((na, pj)) = both() else { return };
        let vocab = pj.cfg().vocab_size;
        let prompt: Vec<i32> = (0..32).map(|i| (i * 11) % vocab as i32).collect();
        let mut chains = Vec::new();
        for be in [&na as &dyn InferenceBackend, &pj as &dyn InferenceBackend] {
            let out = be.prefill_fresh("fp32", &prompt).unwrap();
            let mut conv = out.conv_state;
            let mut ssm = out.ssm_state;
            let mut tok = argmax(&out.logits[31 * vocab..32 * vocab]) as i32;
            let mut chain = vec![tok];
            for _ in 0..12 {
                let d = be.decode("fp32", 1, &conv, &ssm, &[tok]).unwrap();
                conv = d.conv_state;
                ssm = d.ssm_state;
                tok = argmax(&d.logits) as i32;
                chain.push(tok);
            }
            chains.push(chain);
        }
        assert_eq!(chains[0], chains[1], "native vs pjrt greedy decode chain");
    }
}
