//! Backend conformance suite: the executable contract every
//! [`InferenceBackend`] must satisfy, as a reusable assertion harness.
//!
//! The coordinator stack assumes more than the trait signatures say: the
//! engine's chunked admission needs prefill state chaining to be exact,
//! the decode batcher needs batching to never change a sequence's tokens
//! (requests are packed and padded by load, so a batch-sensitive backend
//! would make outputs depend on traffic), `forward_logits` must agree
//! with prefill-then-decode chaining, the bucket lists must be sane (and
//! include batch 1 — the admission path's remainder steps), `zero_state`
//! must match the model's state shapes, and carried state must fully
//! determine continuation ([`check_state_reuse`] — the property the
//! [`crate::statecache`] prefix cache banks on).  Each `check_*`
//! function asserts one of those properties against any backend;
//! [`run_all`] runs the lot.
//!
//! Instantiations: [`NativeBackend`] unconditionally (every host), and
//! [`PjrtBackend`] gated on compiled artifacts — a future backend gets
//! the same coverage by adding one test that calls [`run_all`].
//!
//! [`NativeBackend`]: super::NativeBackend
//! [`PjrtBackend`]: super::PjrtBackend

use crate::coordinator::request::argmax;

use super::bucket::full_bucket_plan;
use super::InferenceBackend;

/// Deterministic token sequence inside the backend's vocabulary.
fn toks(n: usize, vocab: usize, seed: usize) -> Vec<i32> {
    (0..n).map(|i| ((i * 17 + seed * 131 + 7) % vocab) as i32).collect()
}

/// Largest advertised decode batch no bigger than `cap` (falls back to the
/// smallest bucket; the lists are never empty by `check_buckets`).
fn batch_at_most(be: &dyn InferenceBackend, cap: usize) -> usize {
    let batches = be.decode_batches();
    batches
        .iter()
        .rev()
        .find(|&&b| b <= cap)
        .copied()
        .unwrap_or(batches[0])
}

/// Bucket lists are non-empty, strictly ascending, and decode includes
/// batch 1 (the engine's admission remainder and the speculative drafter
/// both decode single sequences).
pub fn check_buckets(be: &dyn InferenceBackend) {
    let prefill = be.prefill_buckets();
    let decode = be.decode_batches();
    assert!(!prefill.is_empty(), "{}: no prefill buckets", be.name());
    assert!(!decode.is_empty(), "{}: no decode batches", be.name());
    for w in prefill.windows(2) {
        assert!(w[0] < w[1], "{}: prefill buckets not ascending: {prefill:?}", be.name());
    }
    for w in decode.windows(2) {
        assert!(w[0] < w[1], "{}: decode batches not ascending: {decode:?}", be.name());
    }
    assert!(prefill[0] >= 1, "{}: zero-length prefill bucket", be.name());
    assert_eq!(decode[0], 1, "{}: decode batch list must include 1", be.name());
}

/// `zero_state` returns all-zero buffers of exactly the model's flat state
/// shapes — the layout `StatePool` pools and `decode` consumes batch-major.
pub fn check_zero_state_shape(be: &dyn InferenceBackend) {
    let cfg = be.cfg();
    let (conv, ssm) = be.zero_state();
    assert_eq!(
        conv.len(),
        cfg.conv_state_len(),
        "{}: conv state is not (n_layer, d_conv-1, conv_dim)",
        be.name()
    );
    assert_eq!(
        ssm.len(),
        cfg.ssm_state_len(),
        "{}: ssm state is not (n_layer, nheads, headdim, d_state)",
        be.name()
    );
    assert!(conv.iter().all(|v| *v == 0.0), "{}: conv state not zeroed", be.name());
    assert!(ssm.iter().all(|v| *v == 0.0), "{}: ssm state not zeroed", be.name());
}

/// Every advertised variant executes prefill (one bucket) and decode
/// (batch 1) with finite, correctly-shaped outputs; an unknown variant
/// name is an error, not a fallback.
pub fn check_variant_coverage(be: &dyn InferenceBackend) {
    let variants = be.variants();
    assert!(!variants.is_empty(), "{}: no variants", be.name());
    let vocab = be.cfg().vocab_size;
    let l = be.prefill_buckets()[0];
    let (cl, sl) = {
        let (c, s) = be.zero_state();
        (c.len(), s.len())
    };
    for v in &variants {
        let t = toks(l, vocab, 1);
        let out = be
            .prefill_fresh(v, &t)
            .unwrap_or_else(|e| panic!("{}: prefill {v} failed: {e}", be.name()));
        assert_eq!(out.logits.len(), l * vocab, "{}: {v} prefill logits shape", be.name());
        assert_eq!(out.conv_state.len(), cl, "{}: {v} prefill conv shape", be.name());
        assert_eq!(out.ssm_state.len(), sl, "{}: {v} prefill ssm shape", be.name());
        assert!(
            out.logits.iter().all(|x| x.is_finite()),
            "{}: {v} prefill logits not finite",
            be.name()
        );
        let d = be
            .decode(v, 1, &out.conv_state, &out.ssm_state, &t[l - 1..])
            .unwrap_or_else(|e| panic!("{}: decode {v} failed: {e}", be.name()));
        assert_eq!(d.logits.len(), vocab, "{}: {v} decode logits shape", be.name());
        assert_eq!(d.conv_state.len(), cl, "{}: {v} decode conv shape", be.name());
        assert_eq!(d.ssm_state.len(), sl, "{}: {v} decode ssm shape", be.name());
        assert!(
            d.logits.iter().all(|x| x.is_finite()),
            "{}: {v} decode logits not finite",
            be.name()
        );
    }
    let t = toks(l, vocab, 1);
    assert!(
        be.prefill_fresh("no-such-variant", &t).is_err(),
        "{}: unknown variant silently accepted",
        be.name()
    );
}

/// Two different bucket-legal chunkings of the same fp32 sequence — the
/// trait-default largest-first plan and a smallest-bucket-only plan —
/// must produce the same per-position logits (token-exact, and close in
/// value), and both must agree with the backend's own `forward_logits`.
/// Fp32 only: the quantized variants calibrate per chunk by design.
pub fn check_prefill_chunking_equivalence(be: &dyn InferenceBackend) {
    let vocab = be.cfg().vocab_size;
    let buckets = be.prefill_buckets();
    let smallest = buckets[0];
    let l = 2 * smallest + 3;
    let t = toks(l, vocab, 2);

    // a chunk plan is (full buckets, decode-step remainder)
    let run = |plan: (Vec<usize>, usize)| -> Vec<f32> {
        let (chunks, rest) = plan;
        let (mut conv, mut ssm) = be.zero_state();
        let mut logits = Vec::with_capacity(l * vocab);
        let mut off = 0usize;
        for b in chunks {
            let out = be.prefill("fp32", &t[off..off + b], &conv, &ssm).unwrap();
            conv = out.conv_state;
            ssm = out.ssm_state;
            logits.extend(out.logits);
            off += b;
        }
        for i in off..off + rest {
            let out = be.decode("fp32", 1, &conv, &ssm, &t[i..i + 1]).unwrap();
            conv = out.conv_state;
            ssm = out.ssm_state;
            logits.extend(out.logits);
        }
        assert_eq!(off + rest, l);
        logits
    };

    let largest_first = run(full_bucket_plan(&buckets, l));
    let smallest_only = run((vec![smallest; 2], l - 2 * smallest));
    let own = be.forward_logits("fp32", &t).unwrap();
    assert_eq!(own.len(), l * vocab, "{}: forward_logits shape", be.name());

    for (name, got) in [("smallest-bucket", &smallest_only), ("forward_logits", &own)] {
        let mut max_err = 0.0f32;
        for (a, b) in got.iter().zip(&largest_first) {
            max_err = max_err.max((a - b).abs());
        }
        assert!(
            max_err < 5e-3,
            "{}: {name} chunking diverged from largest-first: err {max_err}",
            be.name()
        );
        for p in 0..l {
            assert_eq!(
                argmax(&got[p * vocab..(p + 1) * vocab]),
                argmax(&largest_first[p * vocab..(p + 1) * vocab]),
                "{}: {name} chunking changed the argmax at position {p}",
                be.name()
            );
        }
    }
}

/// Batched decode must be *token-exact* with single-sequence decode for
/// every variant — the engine packs concurrent requests (plus padding)
/// into batches, so a batch-sensitive backend would make a request's
/// output depend on unrelated traffic.
pub fn check_batched_decode_matches_singles(be: &dyn InferenceBackend) {
    let vocab = be.cfg().vocab_size;
    let b = batch_at_most(be, 4);
    for v in be.variants() {
        // distinct per-sequence states: one decode step over distinct
        // tokens from the zero state (cheap, and legal on every backend)
        let mut convs = Vec::new();
        let mut ssms = Vec::new();
        let mut next: Vec<i32> = Vec::new();
        for s in 0..b {
            let (conv, ssm) = be.zero_state();
            let t = [((s * 37 + 11) % vocab) as i32];
            let out = be.decode(&v, 1, &conv, &ssm, &t).unwrap();
            next.push(argmax(&out.logits) as i32);
            convs.push(out.conv_state);
            ssms.push(out.ssm_state);
        }
        let conv_b: Vec<f32> = convs.concat();
        let ssm_b: Vec<f32> = ssms.concat();
        let batched = be.decode(&v, b, &conv_b, &ssm_b, &next).unwrap();
        for s in 0..b {
            let single = be.decode(&v, 1, &convs[s], &ssms[s], &next[s..s + 1]).unwrap();
            assert_eq!(
                argmax(&single.logits),
                argmax(&batched.logits[s * vocab..(s + 1) * vocab]),
                "{}: variant {v} batch {b} changed seq {s}'s token",
                be.name()
            );
        }
    }
}

/// The state-reuse contract the `statecache` subsystem banks on:
/// prefilling a prefix, carrying the returned (conv, ssm) state — even
/// across unrelated interleaved calls — and then prefilling the remaining
/// chunks must reproduce the continuous chained run **bit-exactly**, for
/// every variant and every bucket-aligned split of the plan.  A backend
/// with hidden per-sequence state (or per-call calibration leakage) would
/// fail here, and a cached boundary snapshot plus suffix prefill would no
/// longer equal the uncached computation.
pub fn check_state_reuse(be: &dyn InferenceBackend) {
    let vocab = be.cfg().vocab_size;
    let buckets = be.prefill_buckets();
    let smallest = buckets[0];
    let l = 3 * smallest + 2;
    let (chunks, rest) = full_bucket_plan(&buckets, l);
    assert!(chunks.len() >= 2, "{}: split test needs >= 2 chunks", be.name());
    for v in be.variants() {
        let t = toks(l, vocab, 4);

        // continuous run: capture every boundary state and all logits
        let (mut conv, mut ssm) = be.zero_state();
        let mut logits: Vec<f32> = Vec::with_capacity(l * vocab);
        let mut boundaries: Vec<(usize, Vec<f32>, Vec<f32>)> = Vec::new();
        let mut off = 0usize;
        for &b in &chunks {
            let out = be.prefill(&v, &t[off..off + b], &conv, &ssm).unwrap();
            conv = out.conv_state;
            ssm = out.ssm_state;
            logits.extend(out.logits);
            off += b;
            boundaries.push((off, conv.clone(), ssm.clone()));
        }
        for i in off..off + rest {
            let out = be.decode(&v, 1, &conv, &ssm, &t[i..i + 1]).unwrap();
            conv = out.conv_state;
            ssm = out.ssm_state;
            logits.extend(out.logits);
        }

        // resume from every boundary snapshot
        for (bi, (boundary, bconv, bssm)) in boundaries.iter().enumerate() {
            // unrelated traffic between prefix and suffix: a backend with
            // hidden per-sequence state would contaminate the resumption
            let decoy = toks(smallest, vocab, 13 + bi);
            let _ = be.prefill_fresh(&v, &decoy).unwrap();

            let (mut rconv, mut rssm) = (bconv.clone(), bssm.clone());
            let mut got: Vec<f32> = Vec::new();
            let mut roff = *boundary;
            for &b in &chunks[bi + 1..] {
                let out = be.prefill(&v, &t[roff..roff + b], &rconv, &rssm).unwrap();
                rconv = out.conv_state;
                rssm = out.ssm_state;
                got.extend(out.logits);
                roff += b;
            }
            for i in roff..roff + rest {
                let out = be.decode(&v, 1, &rconv, &rssm, &t[i..i + 1]).unwrap();
                rconv = out.conv_state;
                rssm = out.ssm_state;
                got.extend(out.logits);
            }
            assert_eq!(
                rconv, conv,
                "{}: {v} split@{boundary}: conv state diverged from the continuous run",
                be.name()
            );
            assert_eq!(
                rssm, ssm,
                "{}: {v} split@{boundary}: ssm state diverged from the continuous run",
                be.name()
            );
            assert_eq!(
                got.as_slice(),
                &logits[boundary * vocab..],
                "{}: {v} split@{boundary}: suffix logits diverged from the continuous run",
                be.name()
            );
        }
    }
}

/// `forward_logits` must chain with decode: prefilling a bucket and then
/// decoding token-by-token yields the same per-position predictions as
/// one `forward_logits` call over the whole sequence.
pub fn check_forward_logits_chaining(be: &dyn InferenceBackend) {
    let vocab = be.cfg().vocab_size;
    let smallest = be.prefill_buckets()[0];
    let l = smallest + 2;
    let t = toks(l, vocab, 3);
    let full = be.forward_logits("fp32", &t).unwrap();

    let pre = be.prefill_fresh("fp32", &t[..smallest]).unwrap();
    let mut conv = pre.conv_state;
    let mut ssm = pre.ssm_state;
    let mut chained: Vec<f32> = pre.logits;
    for i in smallest..l {
        let out = be.decode("fp32", 1, &conv, &ssm, &t[i..i + 1]).unwrap();
        conv = out.conv_state;
        ssm = out.ssm_state;
        chained.extend(out.logits);
    }
    for p in 0..l {
        assert_eq!(
            argmax(&chained[p * vocab..(p + 1) * vocab]),
            argmax(&full[p * vocab..(p + 1) * vocab]),
            "{}: prefill+decode chain disagrees with forward_logits at {p}",
            be.name()
        );
    }
}

/// Run every conformance check against one backend.
pub fn run_all(be: &dyn InferenceBackend) {
    check_buckets(be);
    check_zero_state_shape(be);
    check_variant_coverage(be);
    check_prefill_chunking_equivalence(be);
    check_batched_decode_matches_singles(be);
    check_forward_logits_chaining(be);
    check_state_reuse(be);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::NativeBackend;

    // -- NativeBackend: unconditional on every host -------------------------

    fn be() -> NativeBackend {
        NativeBackend::synthetic(crate::backend::native::SYNTHETIC_SEED)
    }

    #[test]
    fn native_buckets() {
        check_buckets(&be());
    }

    #[test]
    fn native_zero_state_shape() {
        check_zero_state_shape(&be());
    }

    #[test]
    fn native_variant_coverage() {
        check_variant_coverage(&be());
    }

    #[test]
    fn native_prefill_chunking_equivalence() {
        check_prefill_chunking_equivalence(&be());
    }

    #[test]
    fn native_batched_decode_matches_singles() {
        check_batched_decode_matches_singles(&be());
    }

    #[test]
    fn native_forward_logits_chaining() {
        check_forward_logits_chaining(&be());
    }

    #[test]
    fn native_state_reuse() {
        check_state_reuse(&be());
    }

    #[test]
    fn native_conforms_with_narrow_buckets() {
        // the harness itself must not assume the default bucket lists
        let be = NativeBackend::synthetic(3).with_buckets(vec![8, 16], vec![1, 2]);
        run_all(&be);
    }

    // -- PjrtBackend: gated on compiled artifacts ---------------------------

    #[cfg(feature = "pjrt")]
    #[test]
    fn pjrt_conforms() {
        use crate::backend::PjrtBackend;
        use crate::model::weights::artifacts_dir;
        if !artifacts_dir().join("manifest.json").exists() {
            return;
        }
        let be = PjrtBackend::load_default().expect("pjrt load");
        run_all(&be);
    }
}
